//! Contract tests for the telemetry subsystem (`rust/src/obs/`): the
//! Prometheus exposition's bucket boundaries and escaping, the
//! merge-determinism guarantee (same samples, any order, byte-identical
//! text), and the span ring's overflow + JSONL drain behavior.
//!
//! Trace state is process-global, so every span assertion lives in ONE
//! test fn — parallel test threads would otherwise race on the ring.

use matroid_coreset::obs::{self, MetricsRegistry};

#[test]
fn histogram_bucket_boundaries_render_cumulatively() {
    let reg = MetricsRegistry::new();
    let h = reg.histogram("lat_seconds", &[("t", "a")]);
    h.observe_us(10); // exactly on the first bound: le="0.00001"
    h.observe_us(11); // just over: le="0.000025"
    h.observe_us(1_000_000); // le="1"
    h.observe_us(99_000_000); // beyond the ladder: +Inf only
    let text = reg.render_prometheus();
    assert!(text.contains("# TYPE lat_seconds histogram\n"), "{text}");
    assert!(text.contains("lat_seconds_bucket{t=\"a\",le=\"0.00001\"} 1\n"), "{text}");
    assert!(text.contains("lat_seconds_bucket{t=\"a\",le=\"0.000025\"} 2\n"), "{text}");
    assert!(text.contains("lat_seconds_bucket{t=\"a\",le=\"1\"} 3\n"), "{text}");
    assert!(text.contains("lat_seconds_bucket{t=\"a\",le=\"10\"} 3\n"), "{text}");
    assert!(text.contains("lat_seconds_bucket{t=\"a\",le=\"+Inf\"} 4\n"), "{text}");
    assert!(text.contains("lat_seconds_sum{t=\"a\"} 100.000021\n"), "{text}");
    assert!(text.contains("lat_seconds_count{t=\"a\"} 4\n"), "{text}");
}

#[test]
fn same_samples_any_order_render_identical_text() {
    let samples = [5u64, 40, 90, 400, 2_000, 2_000, 80_000, 20_000_000];
    let a = MetricsRegistry::new();
    let b = MetricsRegistry::new();
    for &us in &samples {
        a.histogram("h_seconds", &[("src", "x")]).observe_us(us);
    }
    for &us in samples.iter().rev() {
        b.histogram("h_seconds", &[("src", "x")]).observe_us(us);
    }
    // registration order differs too: exposition sorts by (name, labels)
    a.counter("z_total", &[]).add(7);
    a.gauge("a_gauge", &[("n", "1")]).set(0.5);
    b.gauge("a_gauge", &[("n", "1")]).set(0.5);
    b.counter("z_total", &[]).add(7);
    assert_eq!(a.render_prometheus(), b.render_prometheus());
    assert_eq!(a.render_json(), b.render_json());
    // integer-microsecond sums are what make the float-free guarantee
    // hold: both orders accumulated exactly 20_082_535us
    assert!(a.render_prometheus().contains("h_seconds_sum{src=\"x\"} 20.082535\n"));
}

#[test]
fn label_values_are_prometheus_escaped() {
    let reg = MetricsRegistry::new();
    reg.counter("esc_total", &[("v", "a\\b\"c\nd")]).inc();
    let text = reg.render_prometheus();
    assert!(text.contains("esc_total{v=\"a\\\\b\\\"c\\nd\"} 1\n"), "{text}");
}

#[test]
fn span_ring_nesting_overflow_and_jsonl_drain() {
    // nesting: inner completes first, carries the outer's id as parent
    obs::trace::enable(16);
    {
        let mut outer = matroid_coreset::span!("outer", "k" = 42);
        outer.tag("extra", "v");
        let _inner = matroid_coreset::span!("inner");
    }
    let (spans, dropped) = obs::trace::drain();
    assert_eq!(dropped, 0);
    assert_eq!(spans.len(), 2, "{spans:#?}");
    let (inner, outer) = (&spans[0], &spans[1]);
    assert_eq!(inner.name, "inner");
    assert_eq!(outer.name, "outer");
    assert_eq!(inner.parent, outer.id);
    assert_eq!(outer.parent, 0);
    assert_eq!(
        outer.tags,
        vec![("k".to_string(), "42".to_string()), ("extra".to_string(), "v".to_string())]
    );

    // overflow: capacity 4, six spans -> the two oldest are overwritten
    obs::trace::enable(4);
    for i in 0..6 {
        let _s = obs::trace::span(&format!("s{i}"));
    }
    let path = std::env::temp_dir().join("dmmc_obs_telemetry_trace.jsonl");
    let path = path.to_str().unwrap().to_string();
    let (written, dropped) = obs::trace::write_jsonl(&path).unwrap();
    assert_eq!(written, 4);
    assert_eq!(dropped, 2);
    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 4);
    for line in &lines {
        assert!(line.starts_with("{\"id\":"), "{line}");
        assert!(line.ends_with('}'), "{line}");
        assert!(line.contains("\"name\":\"s"), "{line}");
        assert!(line.contains("\"start_us\":"), "{line}");
        assert!(line.contains("\"dur_us\":"), "{line}");
    }
    assert!(lines[0].contains("\"name\":\"s2\""), "oldest survivor is s2: {text}");
    std::fs::remove_file(&path).ok();

    // disabled tracing produces inert guards and an empty ring
    obs::trace::disable();
    drop(obs::trace::span("off"));
    let (spans, dropped) = obs::trace::drain();
    assert!(spans.is_empty());
    assert_eq!(dropped, 0);
}
