//! Integration: the coreset guarantee itself.
//!
//! On instances small enough to brute-force, the (1-eps)-coreset property
//! (Definition 3) is checked directly: for every diversity function and
//! matroid type — the full Lemma-2 grid of all six objectives (Table 1
//! plus remote-edge, whose max-min value moves by at most 2r under
//! coreset substitution) under partition and transversal matroids, seeded
//! deterministically — the best independent k-set inside the coreset must
//! be within (1 - eps) of the best independent k-set of the whole input.

use matroid_coreset::algo::exhaustive::exhaustive_best;
use matroid_coreset::algo::seq_coreset::seq_coreset;
use matroid_coreset::algo::stream_coreset::stream_coreset;
use matroid_coreset::algo::Budget;
use matroid_coreset::core::Dataset;
use matroid_coreset::data::synth;
use matroid_coreset::diversity::{Objective, ALL_OBJECTIVES};
use matroid_coreset::matroid::{Matroid, PartitionMatroid, TransversalMatroid, UniformMatroid};
use matroid_coreset::runtime::ScalarEngine;

/// Optimum over the FULL dataset by exhaustive search (small n only).
fn brute_optimum(ds: &Dataset, m: &dyn Matroid, k: usize, obj: Objective) -> f64 {
    let all: Vec<usize> = (0..ds.n()).collect();
    exhaustive_best(ds, &m, k, &all, obj, &ScalarEngine::new())
        .unwrap()
        .diversity
}

fn coreset_optimum(
    ds: &Dataset,
    m: &dyn Matroid,
    k: usize,
    obj: Objective,
    coreset: &[usize],
) -> f64 {
    exhaustive_best(ds, &m, k, coreset, obj, &ScalarEngine::new())
        .unwrap()
        .diversity
}

#[test]
fn seq_coreset_epsilon_guarantee_sum_partition() {
    // small instance, eps = 0.5 -> coreset optimum >= 0.5 * optimum
    let ds = synth::clustered(60, 2, 6, 0.05, 3, 1);
    let m = PartitionMatroid::new(vec![2, 2, 2]);
    let k = 4;
    let eps = 0.5;
    let cs = seq_coreset(&ds, &m, k, Budget::Epsilon(eps), &ScalarEngine::new()).unwrap();
    let opt = brute_optimum(&ds, &m, k, Objective::Sum);
    let cs_opt = coreset_optimum(&ds, &m, k, Objective::Sum, &cs.indices);
    assert!(
        cs_opt >= (1.0 - eps) * opt - 1e-9,
        "coreset {cs_opt} < (1-eps) * {opt}"
    );
}

#[test]
fn seq_coreset_guarantee_all_objectives_uniform() {
    let ds = synth::clustered(40, 2, 5, 0.05, 1, 2);
    let m = UniformMatroid::new(4);
    let k = 4;
    let eps = 0.5;
    let cs = seq_coreset(&ds, &m, k, Budget::Epsilon(eps), &ScalarEngine::new()).unwrap();
    for obj in ALL_OBJECTIVES {
        let opt = brute_optimum(&ds, &m, k, obj);
        let cs_opt = coreset_optimum(&ds, &m, k, obj, &cs.indices);
        assert!(
            cs_opt >= (1.0 - eps) * opt - 1e-9,
            "{obj:?}: {cs_opt} < (1-eps) * {opt}"
        );
    }
}

#[test]
fn seq_coreset_guarantee_all_objectives_partition() {
    // Lemma 2 for every Table-1 objective under a partition matroid
    let ds = synth::clustered(42, 2, 5, 0.05, 3, 11);
    let m = PartitionMatroid::new(vec![2, 2, 2]);
    let k = 4;
    let eps = 0.5;
    let cs = seq_coreset(&ds, &m, k, Budget::Epsilon(eps), &ScalarEngine::new()).unwrap();
    for obj in ALL_OBJECTIVES {
        let opt = brute_optimum(&ds, &m, k, obj);
        let cs_opt = coreset_optimum(&ds, &m, k, obj, &cs.indices);
        assert!(
            cs_opt >= (1.0 - eps) * opt - 1e-9,
            "partition {obj:?}: {cs_opt} < (1-eps) * {opt}"
        );
    }
}

#[test]
fn seq_coreset_guarantee_all_objectives_transversal() {
    // Lemma 2 for every Table-1 objective under a transversal matroid
    let ds = synth::wikisim(50, 3);
    let m = TransversalMatroid::new();
    let k = 3;
    let eps = 0.5;
    let cs = seq_coreset(&ds, &m, k, Budget::Epsilon(eps), &ScalarEngine::new()).unwrap();
    for obj in ALL_OBJECTIVES {
        let opt = brute_optimum(&ds, &m, k, obj);
        let cs_opt = coreset_optimum(&ds, &m, k, obj, &cs.indices);
        assert!(
            cs_opt >= (1.0 - eps) * opt - 1e-9,
            "transversal {obj:?}: {cs_opt} < (1-eps) * {opt}"
        );
    }
}

#[test]
fn seq_coreset_guarantee_transversal() {
    let ds = synth::wikisim(50, 3);
    let m = TransversalMatroid::new();
    let k = 3;
    let eps = 0.5;
    let cs = seq_coreset(&ds, &m, k, Budget::Epsilon(eps), &ScalarEngine::new()).unwrap();
    let opt = brute_optimum(&ds, &m, k, Objective::Sum);
    let cs_opt = coreset_optimum(&ds, &m, k, Objective::Sum, &cs.indices);
    assert!(cs_opt >= (1.0 - eps) * opt - 1e-9, "{cs_opt} < {opt}");
}

#[test]
fn stream_coreset_guarantee_sum() {
    let ds = synth::clustered(60, 2, 6, 0.05, 3, 4);
    let m = PartitionMatroid::new(vec![2, 2, 2]);
    let k = 4;
    let eps = 0.5;
    let order: Vec<usize> = (0..ds.n()).collect();
    let (cs, _) = stream_coreset(&ds, &m, k, eps, &order);
    let opt = brute_optimum(&ds, &m, k, Objective::Sum);
    let cs_opt = coreset_optimum(&ds, &m, k, Objective::Sum, &cs.indices);
    assert!(
        cs_opt >= (1.0 - eps) * opt - 1e-9,
        "stream coreset {cs_opt} < (1-eps) * {opt}"
    );
}

#[test]
fn tighter_epsilon_gives_bigger_better_coreset() {
    let ds = synth::clustered(80, 2, 8, 0.08, 4, 5);
    let m = PartitionMatroid::new(vec![2; 4]);
    let k = 4;
    let engine = ScalarEngine::new();
    let loose = seq_coreset(&ds, &m, k, Budget::Epsilon(0.9), &engine).unwrap();
    let tight = seq_coreset(&ds, &m, k, Budget::Epsilon(0.2), &engine).unwrap();
    assert!(tight.n_clusters >= loose.n_clusters);
    assert!(tight.radius <= loose.radius + 1e-12);
    let d_loose = coreset_optimum(&ds, &m, k, Objective::Sum, &loose.indices);
    let d_tight = coreset_optimum(&ds, &m, k, Objective::Sum, &tight.indices);
    assert!(d_tight >= d_loose - 1e-9);
}

#[test]
fn coreset_radius_satisfies_equation_1() {
    // Equation (1): r(C, Z) <= (eps/4) rho_{S,k}; with Lemma 1 we can only
    // check the derived bound r <= eps*Delta/(16k) <= (eps/4) rho.
    let ds = synth::uniform_cube(200, 2, 6);
    let m = UniformMatroid::new(5);
    let (k, eps) = (5, 0.6);
    let cs = seq_coreset(&ds, &m, k, Budget::Epsilon(eps), &ScalarEngine::new()).unwrap();
    let diam = ds.diameter_exact();
    assert!(cs.radius <= eps * diam / (16.0 * k as f64) + 1e-9);
}

#[test]
fn general_matroid_coreset_contains_opt_when_clusters_degenerate() {
    // With tau = n every cluster is a singleton: the coreset IS the input,
    // so the guarantee is trivially exact — sanity-check the plumbing.
    let ds = synth::uniform_cube(25, 2, 7);
    let m = UniformMatroid::new(3);
    let cs = seq_coreset(&ds, &m, 3, Budget::Clusters(25), &ScalarEngine::new()).unwrap();
    let opt = brute_optimum(&ds, &m, 3, Objective::Sum);
    let cs_opt = coreset_optimum(&ds, &m, 3, Objective::Sum, &cs.indices);
    assert!((opt - cs_opt).abs() < 1e-9);
}
