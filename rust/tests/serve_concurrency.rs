//! Integration: the multi-tenant query server (`matroid_coreset::serve`).
//!
//! Pins the serving-layer acceptance properties:
//!
//! * **coalescing** — M threads firing the identical `(spec, epoch)`
//!   request produce bit-identical answers from exactly one cold
//!   computation (misses == 1, everyone else hit or coalesced);
//! * **epoch stamping** — queries racing appends never mix results
//!   across epochs: every answer stamped with epoch E is bit-identical
//!   to every other epoch-E answer, and the final state replays to the
//!   same bits in a reference single-threaded service;
//! * **warm restarts** — a tenant saved with its result-cache sidecar
//!   answers the same query from cache (zero distance evals) after a
//!   full reload, bit-identically;
//! * **error accounting** — a failing query counts as an error, never a
//!   miss;
//! * **the TCP front end** — a real socket roundtrip: query cold, query
//!   warm, mutate, query cold again, clean shutdown;
//! * **panic containment** — a request that panics mid-execution (the
//!   `DEBUG <tenant> panic` fault injector) answers `ERR internal`,
//!   charges the tenant's error counter, and leaves every worker in the
//!   pool serviceable;
//! * **telemetry reconciliation** — after a coalesced burst plus an
//!   error, the `METRICS` exposition fetched over TCP agrees exactly
//!   with `ServiceStats` (hits + misses + coalesced + errors == queries,
//!   counter for counter).

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::TcpStream;
use std::sync::{Arc, Barrier};
use std::thread;
use std::time::Duration;

use matroid_coreset::data::synth;
use matroid_coreset::index::tree::{CoresetIndex, IndexConfig};
use matroid_coreset::index::{store, DistEvals, IndexSnapshot, QueryResult, QueryService, QuerySpec};
use matroid_coreset::matroid::UniformMatroid;
use matroid_coreset::runtime::EngineKind;
use matroid_coreset::serve::{spawn, InflightSlot, QuerySource, ServeState};

fn snapshot(n: usize, ingest: usize, seed: u64) -> IndexSnapshot {
    let ds = synth::uniform_cube(n, 2, seed);
    let m = UniformMatroid::new(4);
    let cfg = IndexConfig {
        engine: EngineKind::Scalar,
        ..IndexConfig::new(4, 8)
    };
    let mut idx = CoresetIndex::new(&ds, &m, cfg);
    idx.ingest(&(0..ingest).collect::<Vec<_>>(), (ingest / 2).max(1)).unwrap();
    IndexSnapshot::capture(&idx, format!("cube:{n}x2"), seed, "uniform:4".into(), ingest)
}

#[test]
fn identical_concurrent_queries_coalesce_onto_one_cold_run() {
    const THREADS: usize = 8;
    let state = ServeState::new(16);
    let snap = snapshot(500, 400, 21);
    let tenant = state.add("main", &snap).unwrap();
    let spec = QuerySpec::sum_local_search(4, EngineKind::Scalar);
    let barrier = Barrier::new(THREADS);

    let answers: Vec<_> = thread::scope(|s| {
        let handles: Vec<_> = (0..THREADS)
            .map(|_| {
                s.spawn(|| {
                    barrier.wait();
                    tenant.query(&spec).unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    // exactly one cold computation ran; every other request was served
    // from the cache or rode the in-flight leader
    let st = tenant.stats();
    assert_eq!(st.queries, THREADS as u64);
    assert_eq!(st.misses, 1, "more than one cold computation: {st:?}");
    assert_eq!(st.errors, 0);
    assert_eq!(st.hits + st.coalesced, (THREADS - 1) as u64, "{st:?}");
    let cold: Vec<_> =
        answers.iter().filter(|a| a.source == QuerySource::Cold).collect();
    assert_eq!(cold.len(), 1, "exactly one answer may be labeled cold");

    // bit-identity across every serving path
    let reference = &answers[0].outcome.result;
    for a in &answers {
        assert_eq!(a.outcome.result.solution, reference.solution);
        assert_eq!(
            a.outcome.result.diversity.to_bits(),
            reference.diversity.to_bits()
        );
        assert_eq!(a.outcome.epoch, answers[0].outcome.epoch);
        if a.source != QuerySource::Cold {
            assert_eq!(a.outcome.dist_evals, DistEvals::CachedZero);
        }
    }
}

#[test]
fn inflight_slot_delivers_results_and_errors_to_waiters() {
    let slot = Arc::new(InflightSlot::new());
    let waiters: Vec<_> = (0..3)
        .map(|_| {
            let slot = Arc::clone(&slot);
            thread::spawn(move || slot.wait())
        })
        .collect();
    thread::sleep(Duration::from_millis(10));
    let result = QueryResult {
        solution: vec![3, 1, 4],
        diversity: 1.5,
        coreset_size: 9,
    };
    slot.publish(Ok(result.clone()));
    for w in waiters {
        let got = w.join().unwrap().unwrap();
        assert_eq!(got.solution, result.solution);
        assert_eq!(got.diversity.to_bits(), result.diversity.to_bits());
    }
    // a waiter arriving after publication returns immediately
    assert!(slot.wait().is_ok());

    let failing = InflightSlot::new();
    failing.publish(Err("leader failed".into()));
    assert_eq!(failing.wait().unwrap_err(), "leader failed");
}

#[test]
fn queries_racing_appends_stay_epoch_consistent() {
    const QUERY_THREADS: usize = 4;
    const QUERIES_EACH: usize = 25;
    let state = ServeState::new(16);
    let snap = snapshot(600, 200, 33);
    let tenant = state.add("main", &snap).unwrap();
    let spec = QuerySpec::sum_local_search(4, EngineKind::Scalar);

    let answers: Vec<(u64, u64, Vec<usize>)> = thread::scope(|s| {
        let appender = s.spawn(|| {
            for _ in 0..8 {
                tenant.append(Some(50), None).unwrap();
                thread::sleep(Duration::from_millis(2));
            }
        });
        let handles: Vec<_> = (0..QUERY_THREADS)
            .map(|_| {
                s.spawn(|| {
                    let mut seen = Vec::new();
                    for _ in 0..QUERIES_EACH {
                        let a = tenant.query(&spec).unwrap();
                        seen.push((
                            a.outcome.epoch,
                            a.outcome.result.diversity.to_bits(),
                            a.outcome.result.solution.clone(),
                        ));
                    }
                    seen
                })
            })
            .collect();
        appender.join().unwrap();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    assert_eq!(tenant.cursor(), 600, "all appends landed");

    // every answer stamped with epoch E must agree bit for bit with
    // every other epoch-E answer — a stale root can never leak into a
    // newer epoch's label
    let mut by_epoch: BTreeMap<u64, (u64, Vec<usize>)> = BTreeMap::new();
    for (epoch, bits, sol) in &answers {
        match by_epoch.get(epoch) {
            None => {
                by_epoch.insert(*epoch, (*bits, sol.clone()));
            }
            Some((b0, s0)) => {
                assert_eq!(bits, b0, "epoch {epoch} answered with two diversities");
                assert_eq!(sol, s0, "epoch {epoch} answered with two solutions");
            }
        }
    }

    // and the settled state replays to the same bits in a fresh
    // single-threaded reference service (cold runs are deterministic
    // given (spec, epoch))
    let settled = tenant.query(&spec).unwrap();
    let snap = tenant.snapshot();
    let (ds, matroid) = store::snapshot_world(&snap).unwrap();
    let idx = CoresetIndex::from_parts(&ds, &*matroid, snap.config(), snap.parts());
    let mut reference = QueryService::new(idx);
    let cold = reference.query(&spec).unwrap();
    assert_eq!(cold.result.solution, settled.outcome.result.solution);
    assert_eq!(
        cold.result.diversity.to_bits(),
        settled.outcome.result.diversity.to_bits()
    );
    assert_eq!(cold.epoch, settled.outcome.epoch);
}

#[test]
fn saved_tenant_restarts_with_a_warm_cache() {
    let dir = std::env::temp_dir();
    let path = dir.join(format!("dmmc_serve_warm_{}.idx", std::process::id()));
    let snap = snapshot(300, 200, 55);
    store::save(&snap, &path).unwrap();
    let spec = QuerySpec::sum_local_search(4, EngineKind::Scalar);

    // first lifetime: load cold, query, save (snapshot + sidecar)
    let state = ServeState::new(8);
    let tenant = state.load("main", &path).unwrap();
    let cold = tenant.query(&spec).unwrap();
    assert_eq!(cold.source, QuerySource::Cold);
    let (saved_path, entries) = tenant.save().unwrap();
    assert_eq!(saved_path, path);
    assert_eq!(entries, 1);
    assert!(store::result_cache_path(&path).exists(), "sidecar written");
    drop(state);

    // second lifetime: the same query is answered from the sidecar-warmed
    // cache, bit-identically, at zero distance evals
    let state = ServeState::new(8);
    let tenant = state.load("main", &path).unwrap();
    let warm = tenant.query(&spec).unwrap();
    assert_eq!(warm.source, QuerySource::Cache, "restart lost the cache");
    assert_eq!(warm.outcome.dist_evals, DistEvals::CachedZero);
    assert_eq!(warm.outcome.result.solution, cold.outcome.result.solution);
    assert_eq!(
        warm.outcome.result.diversity.to_bits(),
        cold.outcome.result.diversity.to_bits()
    );
    let st = tenant.stats();
    assert_eq!((st.hits, st.misses), (1, 0));

    // a mutation invalidates the persisted entries too: next query is cold
    tenant.append(Some(50), None).unwrap();
    assert_eq!(tenant.query(&spec).unwrap().source, QuerySource::Cold);

    std::fs::remove_file(&path).ok();
    std::fs::remove_file(store::result_cache_path(&path)).ok();
}

#[test]
fn failed_queries_count_as_errors_not_misses() {
    let state = ServeState::new(8);
    let snap = snapshot(100, 60, 77);
    let tenant = state.add("main", &snap).unwrap();
    // k above the index's k_max must fail cleanly...
    let bad = QuerySpec::sum_local_search(10, EngineKind::Scalar);
    assert!(tenant.query(&bad).is_err());
    let st = tenant.stats();
    assert_eq!((st.queries, st.errors, st.misses, st.hits), (1, 1, 0, 0));
    // ...and leave the tenant fully serviceable
    let ok = QuerySpec::sum_local_search(3, EngineKind::Scalar);
    assert_eq!(tenant.query(&ok).unwrap().source, QuerySource::Cold);
    assert_eq!(tenant.query(&ok).unwrap().source, QuerySource::Cache);
}

fn ask_on(
    reader: &mut BufReader<TcpStream>,
    writer: &mut BufWriter<TcpStream>,
    line: &str,
) -> String {
    writeln!(writer, "{line}").unwrap();
    writer.flush().unwrap();
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    reply.trim_end().to_string()
}

#[test]
fn poisoned_requests_do_not_kill_the_worker_pool() {
    const WORKERS: usize = 2;
    let state = Arc::new(ServeState::new(8));
    let snap = snapshot(200, 120, 99);
    let tenant = state.add("main", &snap).unwrap();
    let handle = spawn(Arc::clone(&state), WORKERS).unwrap();

    // hold one connection per worker so every worker in the pool sees a
    // poisoned request
    let mut conns: Vec<(BufReader<TcpStream>, BufWriter<TcpStream>)> = (0..WORKERS)
        .map(|_| {
            let stream = TcpStream::connect(handle.addr).unwrap();
            let reader = BufReader::new(stream.try_clone().unwrap());
            (reader, BufWriter::new(stream))
        })
        .collect();
    for (r, w) in conns.iter_mut() {
        assert_eq!(ask_on(r, w, "PING"), "OK pong");
    }

    // the injected fault panics inside execute(); the per-request
    // containment must answer a structured internal error on the same
    // connection instead of tearing it (or the worker) down
    for (r, w) in conns.iter_mut() {
        let reply = ask_on(r, w, "DEBUG main panic");
        assert!(reply.starts_with("ERR internal "), "{reply}");
        assert!(reply.contains("injected fault"), "{reply}");
    }
    assert_eq!(tenant.stats().errors, WORKERS as u64, "panics must be charged as tenant errors");

    // every worker is still serviceable on its original connection...
    for (r, w) in conns.iter_mut() {
        assert_eq!(ask_on(r, w, "PING"), "OK pong", "worker died after a poisoned request");
        let q = ask_on(r, w, "QUERY main sum 4");
        assert!(q.starts_with("OK query tenant=main"), "{q}");
    }

    // ...a panic against an unknown tenant is an ordinary ERR (the fault
    // injector validates the tenant before detonating)...
    {
        let (r, w) = &mut conns[0];
        let reply = ask_on(r, w, "DEBUG nosuch panic");
        assert!(reply.starts_with("ERR "), "{reply}");
        assert!(!reply.starts_with("ERR internal"), "{reply}");
    }

    // ...and a fresh connection is still accepted after the poison round
    drop(conns.pop());
    let stream = TcpStream::connect(handle.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = BufWriter::new(stream);
    assert_eq!(ask_on(&mut reader, &mut writer, "PING"), "OK pong");

    drop(reader);
    drop(writer);
    drop(conns);
    handle.shutdown().unwrap();
}

#[test]
fn metrics_reconcile_with_stats_after_a_coalesced_burst() {
    const THREADS: usize = 8;
    let state = Arc::new(ServeState::new(16));
    let snap = snapshot(500, 400, 13);
    let tenant = state.add("main", &snap).unwrap();
    let spec = QuerySpec::sum_local_search(4, EngineKind::Scalar);
    let barrier = Barrier::new(THREADS);
    thread::scope(|s| {
        for _ in 0..THREADS {
            s.spawn(|| {
                barrier.wait();
                tenant.query(&spec).unwrap();
            });
        }
    });
    // one failing query (k above k_max) and one warm hit, so every
    // outcome counter is exercised: hit, miss, coalesced, error
    assert!(tenant.query(&QuerySpec::sum_local_search(10, EngineKind::Scalar)).is_err());
    assert_eq!(tenant.query(&spec).unwrap().source, QuerySource::Cache);
    let st = state.total_stats();
    assert_eq!(st.queries, THREADS as u64 + 2);

    // fetch the exposition over a real socket: header, N lines, `# EOF`
    let handle = spawn(Arc::clone(&state), 2).unwrap();
    let stream = TcpStream::connect(handle.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = BufWriter::new(stream);
    writeln!(writer, "METRICS").unwrap();
    writer.flush().unwrap();
    let mut header = String::new();
    reader.read_line(&mut header).unwrap();
    let header = header.trim_end();
    assert!(header.starts_with("OK metrics lines="), "{header}");
    let n: usize = header.rsplit('=').next().unwrap().parse().unwrap();
    let mut body = Vec::new();
    loop {
        let mut line = String::new();
        assert!(reader.read_line(&mut line).unwrap() > 0, "reply ended before # EOF");
        let line = line.trim_end().to_string();
        if line == "# EOF" {
            break;
        }
        body.push(line);
    }
    assert_eq!(body.len(), n, "header line count matches the exposition");

    // the registry must reconcile with ServiceStats counter for counter:
    // telemetry is a mirror of the result path, never a second opinion
    let sum_family = |family: &str| -> u64 {
        body.iter()
            .filter_map(|l| {
                let rest = l.strip_prefix(family)?;
                if !rest.starts_with('{') && !rest.starts_with(' ') {
                    return None;
                }
                l.rsplit(' ').next().unwrap().parse::<u64>().ok()
            })
            .sum()
    };
    assert_eq!(sum_family("dmmc_queries_total"), st.queries, "{body:#?}");
    assert_eq!(sum_family("dmmc_cache_hits_total"), st.hits);
    assert_eq!(sum_family("dmmc_cache_misses_total"), st.misses);
    assert_eq!(sum_family("dmmc_coalesced_total"), st.coalesced);
    assert_eq!(sum_family("dmmc_errors_total"), st.errors);
    assert_eq!(
        st.hits + st.misses + st.coalesced + st.errors,
        st.queries,
        "every request resolves to exactly one outcome: {st:?}"
    );
    assert!(
        body.iter().any(|l| l.starts_with("dmmc_query_latency_seconds_bucket{")),
        "latency histogram exposed: {body:#?}"
    );
    // gauges are stamped from tenant status at METRICS time
    assert!(body.iter().any(|l| l.starts_with("dmmc_tenant_epoch{tenant=\"main\"}")), "{body:#?}");
    assert!(body.iter().any(|l| l.starts_with("dmmc_index_live_fraction{tenant=\"main\"}")));

    writeln!(writer, "QUIT").unwrap();
    writer.flush().unwrap();
    drop(reader);
    drop(writer);
    handle.shutdown().unwrap();
}

#[test]
fn tcp_roundtrip_serves_queries_and_mutations() {
    let state = Arc::new(ServeState::new(8));
    let snap = snapshot(300, 200, 91);
    state.add("main", &snap).unwrap();
    let handle = spawn(Arc::clone(&state), 2).unwrap();

    let stream = TcpStream::connect(handle.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = BufWriter::new(stream);
    let mut ask = |line: &str| -> String {
        writeln!(writer, "{line}").unwrap();
        writer.flush().unwrap();
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        reply.trim_end().to_string()
    };

    assert_eq!(ask("PING"), "OK pong");
    assert_eq!(ask("TENANTS"), "OK tenants main");

    let cold = ask("QUERY main sum 4");
    assert!(cold.starts_with("OK query tenant=main source=cold"), "{cold}");
    let warm = ask("QUERY main sum 4");
    assert!(warm.starts_with("OK query tenant=main source=cache"), "{warm}");
    // the wire carries the diversity bits: cache hit is bit-identical
    let bits = |reply: &str| {
        reply
            .split_whitespace()
            .find_map(|tok| tok.strip_prefix("bits="))
            .unwrap()
            .to_string()
    };
    assert_eq!(bits(&cold), bits(&warm));

    let append = ask("APPEND main 50");
    assert!(append.starts_with("OK append tenant=main"), "{append}");
    let after = ask("QUERY main sum 4");
    assert!(after.starts_with("OK query tenant=main source=cold"), "post-append query must be cold: {after}");

    let del = ask("DELETE main 0..3");
    assert!(del.starts_with("OK delete tenant=main requested=3"), "{del}");
    assert!(ask("QUERY main sum 4").contains("source=cold"));

    let stats = ask("STATS main");
    assert!(stats.starts_with("OK stats tenant=main queries=4"), "{stats}");

    // malformed and unknown requests answer ERR without dropping the line
    assert!(ask("QUERY nosuch sum 4").starts_with("ERR "));
    assert!(ask("FROBNICATE").starts_with("ERR "));
    assert_eq!(ask("QUIT"), "OK bye");

    // release the worker before joining the server
    drop(reader);
    drop(writer);
    handle.shutdown().unwrap();
}
