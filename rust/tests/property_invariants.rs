//! Property-based invariants (mini-proptest): matroid axioms, coreset
//! feasibility, metric axioms, diversity-function relations, and
//! local-search postconditions — all over randomized instances.

use matroid_coreset::algo::local_search::{
    local_search_sum, LocalSearchMode, LocalSearchParams, REANCHOR_EPOCH,
};
use matroid_coreset::algo::seq_coreset::seq_coreset;
use matroid_coreset::algo::stream_coreset::stream_coreset_tau;
use matroid_coreset::algo::Budget;
use matroid_coreset::core::{Dataset, Metric};
use matroid_coreset::diversity::{
    diversity, diversity_with_engine, mst, tsp, Objective, ALL_OBJECTIVES,
};
use matroid_coreset::matroid::{
    maximal_independent, Matroid, PartitionMatroid, TransversalMatroid, UniformMatroid,
};
use matroid_coreset::prop_assert;
use matroid_coreset::proptest::{check, Gen};
use matroid_coreset::runtime::{BatchEngine, DistanceEngine, ScalarEngine};
use matroid_coreset::util::rng::Rng;

fn random_multilabel_dataset(g: &mut Gen, max_n: usize) -> Dataset {
    let n = g.usize_in(4, max_n);
    let dim = g.usize_in(1, 6);
    let ncat = g.usize_in(2, 6) as u32;
    let coords = g.vec_f32(n * dim, 2.0);
    let categories = (0..n)
        .map(|_| {
            let c = g.usize_in(1, 2);
            (0..c).map(|_| g.rng.below(ncat as usize) as u32).collect()
        })
        .collect();
    Dataset::new(dim, Metric::Euclidean, coords, categories, ncat, "prop")
}

fn random_single_label_dataset(g: &mut Gen, max_n: usize) -> Dataset {
    let n = g.usize_in(4, max_n);
    let dim = g.usize_in(1, 6);
    let ncat = g.usize_in(2, 5) as u32;
    let coords = g.vec_f32(n * dim, 2.0);
    let categories = (0..n)
        .map(|_| vec![g.rng.below(ncat as usize) as u32])
        .collect();
    Dataset::new(dim, Metric::Euclidean, coords, categories, ncat, "prop")
}

/// Hereditary + augmentation axioms for a matroid on a random instance.
fn check_matroid_axioms(g: &mut Gen, ds: &Dataset, m: &dyn Matroid) -> Result<(), String> {
    let n = ds.n();
    // hereditary: random independent set -> every one-element-removed subset
    let size = g.usize_in(1, n.min(6));
    let candidate = g.subset(n, size);
    if m.is_independent(ds, &candidate) {
        for drop in 0..candidate.len() {
            let sub: Vec<usize> = candidate
                .iter()
                .enumerate()
                .filter(|(i, _)| *i != drop)
                .map(|(_, &x)| x)
                .collect();
            prop_assert!(
                m.is_independent(ds, &sub),
                "hereditary violated: {candidate:?} indep but {sub:?} not"
            );
        }
    }
    // augmentation: |A| > |B| both independent -> some x in A\B extends B
    let a = maximal_independent(m, ds, &g.rng.permutation(n), 5);
    let b = maximal_independent(
        m,
        ds,
        &g.rng.permutation(n),
        a.len().saturating_sub(1).max(1),
    );
    if a.len() > b.len() && m.is_independent(ds, &a) && m.is_independent(ds, &b) {
        let found = a.iter().any(|&x| !b.contains(&x) && m.can_extend(ds, &b, x));
        prop_assert!(found, "augmentation violated: |A|={} |B|={}", a.len(), b.len());
    }
    Ok(())
}

#[test]
fn prop_partition_matroid_axioms() {
    check("partition-axioms", 60, |g| {
        let ds = random_single_label_dataset(g, 30);
        let caps: Vec<usize> = (0..ds.n_categories).map(|_| g.usize_in(0, 3)).collect();
        let m = PartitionMatroid::new(caps);
        check_matroid_axioms(g, &ds, &m)
    });
}

#[test]
fn prop_transversal_matroid_axioms() {
    check("transversal-axioms", 60, |g| {
        let ds = random_multilabel_dataset(g, 25);
        let m = TransversalMatroid::new();
        check_matroid_axioms(g, &ds, &m)
    });
}

#[test]
fn prop_coreset_contains_feasible_kset() {
    // if the input contains an independent k-set, so must the coreset
    check("coreset-feasible", 30, |g| {
        let ds = random_single_label_dataset(g, 60);
        let caps: Vec<usize> = (0..ds.n_categories).map(|_| g.usize_in(1, 3)).collect();
        let m = PartitionMatroid::new(caps);
        let k = g.usize_in(2, 5);
        let full_rank = maximal_independent(&m, &ds, &(0..ds.n()).collect::<Vec<_>>(), k).len();
        let tau = g.usize_in(2, 10);
        let cs = seq_coreset(&ds, &m, k, Budget::Clusters(tau), &ScalarEngine::new())
            .map_err(|e| e.to_string())?;
        let cs_rank = maximal_independent(&m, &ds, &cs.indices, k).len();
        prop_assert!(
            cs_rank >= full_rank.min(k),
            "coreset rank {cs_rank} < min(full rank {full_rank}, k {k})"
        );
        Ok(())
    });
}

#[test]
fn prop_stream_coreset_feasible_and_bounded() {
    check("stream-coreset-feasible", 25, |g| {
        let ds = random_single_label_dataset(g, 60);
        let caps: Vec<usize> = (0..ds.n_categories).map(|_| g.usize_in(1, 3)).collect();
        let m = PartitionMatroid::new(caps);
        let k = g.usize_in(2, 5);
        let tau = g.usize_in(2, 8);
        let order = g.rng.permutation(ds.n());
        let (cs, _stats) = stream_coreset_tau(&ds, &m, k, tau, &order);
        prop_assert!(cs.n_clusters <= tau, "centers {} > tau {tau}", cs.n_clusters);
        let full_rank = maximal_independent(&m, &ds, &(0..ds.n()).collect::<Vec<_>>(), k).len();
        let cs_rank = maximal_independent(&m, &ds, &cs.indices, k).len();
        prop_assert!(cs_rank >= full_rank.min(k), "{cs_rank} < {full_rank}");
        Ok(())
    });
}

#[test]
fn prop_mst_leq_tsp_leq_twice_mst() {
    check("mst-tsp-sandwich", 40, |g| {
        let n = g.usize_in(3, 11);
        let dim = g.usize_in(1, 4);
        let coords = g.vec_f32(n * dim, 3.0);
        let ds = Dataset::new(dim, Metric::Euclidean, coords, vec![vec![0]; n], 1, "p");
        let set: Vec<usize> = (0..n).collect();
        let w_mst = mst::mst_weight(&ds, &set);
        let w_tsp = tsp::tsp_weight(&ds, &set);
        prop_assert!(w_tsp >= w_mst - 1e-9, "tsp {w_tsp} < mst {w_mst}");
        prop_assert!(w_tsp <= 2.0 * w_mst + 1e-9, "tsp {w_tsp} > 2 mst {w_mst}");
        Ok(())
    });
}

#[test]
fn prop_cross_objective_relations() {
    // star and bipartition both count a subset of the pairwise distances
    // that sum counts (a star is k-1 of them, a balanced cut at most
    // floor(k/2)*ceil(k/2)), so neither can exceed the sum objective
    check("cross-objective-relations", 40, |g| {
        let n = g.usize_in(4, 12);
        let dim = g.usize_in(1, 4);
        let coords = g.vec_f32(n * dim, 2.0);
        let ds = Dataset::new(dim, Metric::Euclidean, coords, vec![vec![0]; n], 1, "p");
        let set: Vec<usize> = (0..n).collect();
        let sum = diversity(&ds, &set, Objective::Sum);
        let star = diversity(&ds, &set, Objective::Star);
        let bip = diversity(&ds, &set, Objective::Bipartition);
        let tol = 1e-9 * sum.max(1.0);
        prop_assert!(star <= sum + tol, "star {star} > sum {sum}");
        prop_assert!(bip <= sum + tol, "bipartition {bip} > sum {sum}");
        Ok(())
    });
}

#[test]
fn prop_objectives_permutation_invariant() {
    // every objective is a function of the *set*: feeding the members in
    // any order must give the same value (up to f64 accumulation order)
    check("objective-permutation-invariance", 30, |g| {
        let n = g.usize_in(3, 10);
        let dim = g.usize_in(1, 4);
        let coords = g.vec_f32(n * dim, 2.0);
        let ds = Dataset::new(dim, Metric::Euclidean, coords, vec![vec![0]; n], 1, "p");
        let set: Vec<usize> = (0..n).collect();
        let shuffled = g.rng.permutation(n);
        for obj in ALL_OBJECTIVES {
            let a = diversity(&ds, &set, obj);
            let b = diversity(&ds, &shuffled, obj);
            prop_assert!(
                (a - b).abs() <= 1e-9 * a.abs().max(1.0),
                "{obj:?} not permutation-invariant: {a} vs {b}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_diversity_equals_engine_paths() {
    // the free function IS the scalar-engine path (bit-equal), and the
    // batch backend must agree bit for bit on every objective — the
    // consumer-level restatement of the engine bit-identity contracts
    check("diversity-engine-equivalence", 20, |g| {
        let n = g.usize_in(2, 12);
        let dim = g.usize_in(1, 4);
        let coords = g.vec_f32(n * dim, 2.0);
        let ds = Dataset::new(dim, Metric::Euclidean, coords, vec![vec![0]; n], 1, "p");
        let batch = BatchEngine::for_dataset(&ds);
        let size = g.usize_in(1, n);
        let set = g.subset(n, size);
        for obj in ALL_OBJECTIVES {
            let base = diversity(&ds, &set, obj);
            let scalar = diversity_with_engine(&ds, &set, obj, &ScalarEngine::new())
                .map_err(|e| e.to_string())?;
            let batched =
                diversity_with_engine(&ds, &set, obj, &batch).map_err(|e| e.to_string())?;
            prop_assert!(
                base.to_bits() == scalar.to_bits(),
                "{obj:?}: free fn {base} != scalar engine {scalar}"
            );
            prop_assert!(
                base.to_bits() == batched.to_bits(),
                "{obj:?}: scalar {base} != batch {batched}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_diversity_linear_under_scaling() {
    // scaling all coordinates by c > 1 scales every diversity linearly
    check("diversity-scaling", 30, |g| {
        let n = g.usize_in(4, 10);
        let coords = g.vec_f32(n * 2, 1.0);
        let scale = g.f64_in(1.5, 4.0) as f32;
        let scaled: Vec<f32> = coords.iter().map(|&v| v * scale).collect();
        let ds1 = Dataset::new(2, Metric::Euclidean, coords, vec![vec![0]; n], 1, "a");
        let ds2 = Dataset::new(2, Metric::Euclidean, scaled, vec![vec![0]; n], 1, "b");
        let set: Vec<usize> = (0..n).collect();
        for obj in [Objective::Sum, Objective::Star, Objective::Tree, Objective::Cycle] {
            let d1 = diversity(&ds1, &set, obj);
            let d2 = diversity(&ds2, &set, obj);
            prop_assert!(
                (d2 - scale as f64 * d1).abs() <= 1e-4 * d2.abs().max(1.0),
                "{obj:?} not linear under scaling: {d1} -> {d2} (x{scale})"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_local_search_postconditions() {
    check("local-search-post", 25, |g| {
        let ds = random_single_label_dataset(g, 40);
        let caps: Vec<usize> = (0..ds.n_categories).map(|_| g.usize_in(1, 3)).collect();
        let m = PartitionMatroid::new(caps);
        let k = g.usize_in(2, 4);
        let cands: Vec<usize> = (0..ds.n()).collect();
        let mut rng = Rng::new(g.rng.next_u64());
        let res = local_search_sum(
            &ds,
            &m,
            k,
            &cands,
            &ScalarEngine::new(),
            LocalSearchParams::default(),
            None,
            &mut rng,
        )
        .unwrap();
        prop_assert!(m.is_independent(&ds, &res.solution), "solution not independent");
        // local optimality: no single swap improves (spot-check a few)
        let div = res.diversity;
        for _ in 0..10 {
            if res.solution.is_empty() {
                break;
            }
            let v = g.rng.below(ds.n());
            if res.solution.contains(&v) {
                continue;
            }
            let upos = g.rng.below(res.solution.len());
            let mut cand = res.solution.clone();
            cand[upos] = v;
            if m.is_independent(&ds, &cand) {
                let nd = matroid_coreset::diversity::sum_diversity(&ds, &cand);
                prop_assert!(
                    nd <= div + 1e-6 * div.max(1.0),
                    "improving swap left: {nd} > {div}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_delta_candidate_sums_stay_within_reanchor_drift() {
    // the incremental AMT arithmetic in isolation: candidate sums
    // maintained by `d(c, v) - d(c, u)` deltas off the exact column store
    // stay within the re-anchor drift bound of a from-scratch sums_to_set
    // over a whole epoch of swaps, and the re-anchor row re-summation
    // restores the from-scratch bits exactly
    check("incremental-delta-drift", 20, |g| {
        let n = g.usize_in(12, 50);
        let dim = g.usize_in(1, 5);
        let coords = g.vec_f32(n * dim, 2.0);
        let ds = Dataset::new(dim, Metric::Euclidean, coords, vec![vec![0]; n], 1, "p");
        let k = g.usize_in(2, 5);
        let engine = BatchEngine::for_dataset(&ds);
        let candidates: Vec<usize> = (0..n).collect();
        let mut sol = g.subset(n, k);
        let mut cols = engine
            .dists_to_points(&ds, &candidates, &sol)
            .map_err(|e| e.to_string())?;
        let mut cand_sums: Vec<f64> = cols.chunks(k).map(|r| r.iter().sum()).collect();
        for step in 0..REANCHOR_EPOCH {
            // a random swap: v in (fresh), sol[upos] out
            let v = loop {
                let v = g.rng.below(n);
                if !sol.contains(&v) {
                    break v;
                }
            };
            let upos = g.rng.below(k);
            sol[upos] = v;
            let col = engine
                .dists_to_points(&ds, &candidates, &sol[upos..upos + 1])
                .map_err(|e| e.to_string())?;
            for (c, s) in cand_sums.iter_mut().enumerate() {
                *s += col[c] - cols[c * k + upos];
                cols[c * k + upos] = col[c];
            }
            let fresh = engine
                .sums_to_set(&ds, &candidates, &sol)
                .map_err(|e| e.to_string())?;
            for (c, (&delta_s, &fresh_s)) in cand_sums.iter().zip(&fresh).enumerate() {
                // 2 fp ops per swap over an epoch, on sums of at most 5
                // normal-scale distances (magnitude ~1e2 worst case):
                // <= 2 * 32 * ulp(1e2) ~ 1e-12 absolute; 1e-11 leaves a
                // margin while still pinning the sums to the last digits
                let bound = 1e-11 * fresh_s.abs().max(1.0);
                prop_assert!(
                    (delta_s - fresh_s).abs() <= bound,
                    "step {step} cand {c}: delta {delta_s} vs fresh {fresh_s}"
                );
            }
        }
        // re-anchor: the columns hold exact distances with true-zero self
        // entries, so row re-summation IS the from-scratch sum
        let fresh = engine
            .sums_to_set(&ds, &candidates, &sol)
            .map_err(|e| e.to_string())?;
        for (c, &want) in fresh.iter().enumerate() {
            let resum: f64 = cols[c * k..(c + 1) * k].iter().sum();
            prop_assert!(
                resum.to_bits() == want.to_bits(),
                "re-anchor row {c}: {resum} vs {want}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_local_search_modes_identical_trajectory() {
    // mode-independence restated as a property over random instances and
    // matroids: incremental and exhaustive-restart walk the same swaps
    check("local-search-mode-identity", 15, |g| {
        let ds = random_single_label_dataset(g, 40);
        let caps: Vec<usize> = (0..ds.n_categories).map(|_| g.usize_in(1, 3)).collect();
        let m = PartitionMatroid::new(caps);
        let k = g.usize_in(2, 4);
        let cands: Vec<usize> = (0..ds.n()).collect();
        let seed = g.rng.next_u64();
        let mut results = Vec::new();
        for mode in [
            LocalSearchMode::Incremental,
            LocalSearchMode::ExhaustiveRestart,
        ] {
            let mut rng = Rng::new(seed);
            let res = local_search_sum(
                &ds,
                &m,
                k,
                &cands,
                &ScalarEngine::new(),
                LocalSearchParams {
                    mode,
                    ..Default::default()
                },
                None,
                &mut rng,
            )
            .unwrap();
            results.push(res);
        }
        prop_assert!(
            results[0].solution == results[1].solution,
            "solutions diverged: {:?} vs {:?}",
            results[0].solution,
            results[1].solution
        );
        prop_assert!(results[0].swaps == results[1].swaps, "swap counts diverged");
        prop_assert!(
            results[0].oracle_calls == results[1].oracle_calls,
            "oracle calls diverged"
        );
        Ok(())
    });
}

#[test]
fn prop_uniform_matroid_unconstrained_equivalence() {
    // under U_{k,n}, greedy maximal always reaches exactly k elements
    check("uniform-equiv", 20, |g| {
        let n = g.usize_in(5, 30);
        let coords = g.vec_f32(n * 2, 1.0);
        let ds = Dataset::new(2, Metric::Euclidean, coords, vec![vec![0]; n], 1, "u");
        let k = g.usize_in(1, n.min(5));
        let m = UniformMatroid::new(k);
        let picked = maximal_independent(&m, &ds, &g.rng.permutation(n), n);
        prop_assert!(picked.len() == k, "uniform rank not reached: {}", picked.len());
        Ok(())
    });
}
