//! Determinism-contract regression tests (the runtime counterpart of the
//! `cargo xtask lint` static pass).
//!
//! The L1 lint bans order-sensitive hash collections from the
//! result-producing modules; these tests pin the *properties* that ban
//! protects.  Rust's `HashMap` draws a fresh `RandomState` per instance,
//! so before the `BTreeMap` conversion two identical calls in the same
//! process could iterate the matching/coverage maps differently — these
//! tests would have caught that:
//!
//! * transversal independence decisions are invariant under the order the
//!   elements (and hence their category constraints) are inserted;
//! * matching witnesses and EXTRACT outputs are bit-identical across
//!   repeated calls and across datasets whose per-point category lists
//!   were supplied in shuffled order (`Dataset::new` normalizes them —
//!   part of the same input-defined-order contract);
//! * whole SeqCoreset runs replay identically;
//! * dynamic index state (tombstones, rebuilds, retention) depends only
//!   on the *set* of deleted rows, never on the order they were listed,
//!   and replays identically across category-insertion-order variants.

use matroid_coreset::algo::seq_coreset::seq_coreset;
use matroid_coreset::algo::{extract::extract, Budget};
use matroid_coreset::core::{Dataset, Metric};
use matroid_coreset::index::{CoresetIndex, IndexConfig, RetentionPolicy};
use matroid_coreset::matroid::{Matroid, TransversalMatroid};
use matroid_coreset::runtime::engine::ScalarEngine;
use matroid_coreset::runtime::EngineKind;
use matroid_coreset::util::rng::Rng;

const N_CATEGORIES: u32 = 6;

/// Coordinates + category lists for a 2-d dataset whose points each carry
/// 1..=3 overlapping categories.
fn raw_data(n: usize, seed: u64) -> (Vec<f32>, Vec<Vec<u32>>) {
    let mut rng = Rng::new(seed);
    let mut coords = Vec::with_capacity(2 * n);
    let mut cats = Vec::with_capacity(n);
    for _ in 0..n {
        coords.push(rng.normal() as f32);
        coords.push(rng.normal() as f32);
        let mut own: Vec<u32> = Vec::new();
        for _ in 0..(1 + rng.below(3)) {
            let c = rng.below(N_CATEGORIES as usize) as u32;
            if !own.contains(&c) {
                own.push(c);
            }
        }
        cats.push(own);
    }
    (coords, cats)
}

/// Build the dataset with every point's category list in a different
/// insertion order (variant 0 = as generated, 1 = reversed, 2+ = seeded
/// shuffles).  `Dataset::new` must normalize all of them identically.
fn dataset_variant(coords: &[f32], cats: &[Vec<u32>], variant: u64) -> Dataset {
    let cats: Vec<Vec<u32>> = cats
        .iter()
        .enumerate()
        .map(|(i, own)| {
            let mut own = own.clone();
            match variant {
                0 => {}
                1 => own.reverse(),
                v => Rng::new(v * 7919 + i as u64).shuffle(&mut own),
            }
            own
        })
        .collect();
    Dataset::new(2, Metric::Euclidean, coords.to_vec(), cats, N_CATEGORIES, "determinism")
}

#[test]
fn category_lists_normalize_identically() {
    let (coords, cats) = raw_data(50, 3);
    let base = dataset_variant(&coords, &cats, 0);
    for variant in 1..4 {
        let ds = dataset_variant(&coords, &cats, variant);
        assert_eq!(ds.categories, base.categories, "variant {variant}");
    }
}

#[test]
fn matching_size_invariant_under_set_order() {
    let (coords, cats) = raw_data(40, 11);
    let ds = dataset_variant(&coords, &cats, 0);
    let mut rng = Rng::new(99);
    for trial in 0..50 {
        let size = 1 + rng.below(8);
        let set = rng.sample_indices(ds.n(), size);
        let want = TransversalMatroid::matching_size(&ds, &set);
        for perm_seed in 0..4u64 {
            let mut shuffled = set.clone();
            Rng::new(1000 + perm_seed).shuffle(&mut shuffled);
            assert_eq!(
                TransversalMatroid::matching_size(&ds, &shuffled),
                want,
                "trial {trial}: matching size changed with element order ({set:?})"
            );
        }
    }
}

#[test]
fn matching_witness_replays_identically_and_is_valid() {
    let (coords, cats) = raw_data(40, 17);
    let ds = dataset_variant(&coords, &cats, 0);
    let m = TransversalMatroid::new();
    let mut rng = Rng::new(5);
    let mut independent_seen = 0;
    for _ in 0..80 {
        let size = 1 + rng.below(6);
        let set = rng.sample_indices(ds.n(), size);
        if !m.is_independent(&ds, &set) {
            continue;
        }
        independent_seen += 1;
        let w1 = TransversalMatroid::matching_witness(&ds, &set).expect("independent");
        let w2 = TransversalMatroid::matching_witness(&ds, &set).expect("independent");
        assert_eq!(w1, w2, "witness must replay bit-identically ({set:?})");
        let mut used = std::collections::BTreeSet::new();
        for (pos, &c) in w1.iter().enumerate() {
            assert!(ds.categories[set[pos]].contains(&c), "witness edge exists");
            assert!(used.insert(c), "witness categories are distinct");
        }
    }
    assert!(independent_seen > 10, "test exercised real matchings");
}

#[test]
fn extract_replays_identically_across_category_insertion_orders() {
    let (coords, cats) = raw_data(60, 23);
    let variants: Vec<Dataset> = (0..4).map(|v| dataset_variant(&coords, &cats, v)).collect();
    let m = TransversalMatroid::new();
    let mut rng = Rng::new(7);
    for trial in 0..20 {
        let size = 5 + rng.below(20);
        let cluster = rng.sample_indices(variants[0].n(), size);
        for k in [2usize, 4, 8] {
            let want = extract(&variants[0], &m, &cluster, k);
            assert_eq!(
                extract(&variants[0], &m, &cluster, k),
                want,
                "trial {trial}, k={k}: extract must replay bit-identically"
            );
            for (v, ds) in variants.iter().enumerate().skip(1) {
                assert_eq!(
                    extract(ds, &m, &cluster, k),
                    want,
                    "trial {trial}, k={k}, variant {v}: extraction changed with \
                     category insertion order"
                );
            }
        }
    }
}

#[test]
fn seq_coreset_replays_identically_across_category_insertion_orders() {
    let (coords, cats) = raw_data(200, 31);
    let m = TransversalMatroid::new();
    let engine = ScalarEngine::new();
    let base = dataset_variant(&coords, &cats, 0);
    let want = seq_coreset(&base, &m, 4, Budget::Clusters(12), &engine)
        .expect("seq_coreset runs")
        .indices;
    assert!(!want.is_empty());
    for variant in 0..4 {
        let ds = dataset_variant(&coords, &cats, variant);
        let got = seq_coreset(&ds, &m, 4, Budget::Clusters(12), &engine)
            .expect("seq_coreset runs")
            .indices;
        assert_eq!(
            got, want,
            "variant {variant}: coreset changed with category insertion order"
        );
    }
}

#[test]
fn index_delete_replays_identically_under_row_order() {
    let (coords, cats) = raw_data(120, 41);
    let ds = dataset_variant(&coords, &cats, 0);
    let m = TransversalMatroid::new();
    let cfg = IndexConfig {
        engine: EngineKind::Scalar,
        ..IndexConfig::new(4, 8)
    };
    let order: Vec<usize> = (0..ds.n()).collect();
    // heavy enough to kill whole nodes and cross rebuild thresholds
    let victims: Vec<usize> = (0..ds.n()).step_by(2).collect();

    let build = |rows: &[usize]| {
        let mut idx = CoresetIndex::new(&ds, &m, cfg);
        idx.ingest(&order, 30).unwrap();
        idx.delete(rows).unwrap();
        idx
    };
    let base = build(&victims);
    for perm in 1..5u64 {
        // the whole batch is tombstoned before any threshold is checked,
        // so one delete call must depend only on the set of rows — shuffle
        // within the call, not across calls
        let mut shuffled = victims.clone();
        Rng::new(perm * 104729).shuffle(&mut shuffled);
        let idx = build(&shuffled);
        assert_eq!(idx.tombstones(), base.tombstones(), "perm {perm}");
        assert_eq!(idx.root(), base.root(), "perm {perm}: delete order changed the tree");
        assert_eq!(idx.epoch(), base.epoch(), "perm {perm}");
        assert_eq!(idx.stats(), base.stats(), "perm {perm}");
    }
}

#[test]
fn dynamic_index_invariant_across_category_insertion_orders() {
    let (coords, cats) = raw_data(150, 43);
    let m = TransversalMatroid::new();
    let victims: Vec<usize> = (0..150).step_by(3).collect();
    let run = |ds: &Dataset, retention: RetentionPolicy| {
        let cfg = IndexConfig {
            engine: EngineKind::Scalar,
            retention,
            ..IndexConfig::new(4, 8)
        };
        let mut idx = CoresetIndex::new(ds, &m, cfg);
        let order: Vec<usize> = (0..ds.n()).collect();
        idx.ingest(&order, 25).unwrap();
        let r = idx.delete(&victims).unwrap();
        (idx.root(), r.root_size, idx.epoch(), *idx.stats())
    };
    let base = dataset_variant(&coords, &cats, 0);
    for retention in [RetentionPolicy::KeepAll, RetentionPolicy::LastSegments(3)] {
        let want = run(&base, retention);
        for variant in 1..4 {
            let ds = dataset_variant(&coords, &cats, variant);
            assert_eq!(
                run(&ds, retention),
                want,
                "variant {variant}, retention {}: dynamic index state changed \
                 with category insertion order",
                retention.name()
            );
        }
    }
}
