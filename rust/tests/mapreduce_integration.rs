//! Integration: MapReduce composability (Theorem 6) and scaling behaviour.

use matroid_coreset::algo::exhaustive::exhaustive_best;
use matroid_coreset::algo::Budget;
use matroid_coreset::data::synth;
use matroid_coreset::diversity::Objective;
use matroid_coreset::mapreduce::{mr_coreset, MapReduceConfig};
use matroid_coreset::matroid::{maximal_independent, PartitionMatroid, UniformMatroid};
use matroid_coreset::runtime::{EngineKind, ScalarEngine};

fn cfg(workers: usize, tau: usize, seed: u64) -> MapReduceConfig {
    MapReduceConfig {
        workers,
        budget: Budget::Clusters(tau),
        second_round_tau: None,
        seed,
        engine: EngineKind::default(),
    }
}

#[test]
fn composability_preserves_near_optimal_solutions() {
    // union-of-shard-coresets must still contain a near-optimal k-set
    let ds = synth::clustered(240, 2, 6, 0.05, 3, 1);
    let m = PartitionMatroid::new(vec![2, 2, 2]);
    let k = 4;
    let all: Vec<usize> = (0..ds.n()).collect();
    let engine = ScalarEngine::new();
    let opt = exhaustive_best(&ds, &m, k, &all, Objective::Sum, &engine)
        .unwrap()
        .diversity;
    for ell in [2usize, 4, 8] {
        let rep = mr_coreset(&ds, &m, k, cfg(ell, 8, 3)).unwrap();
        let got = exhaustive_best(&ds, &m, k, &rep.coreset.indices, Objective::Sum, &engine)
            .unwrap()
            .diversity;
        assert!(
            got >= 0.5 * opt,
            "ell={ell}: coreset optimum {got} below half of {opt}"
        );
    }
}

#[test]
fn paper_tau_split_protocol() {
    // Fig. 3 protocol: global tau fixed, each worker gets tau/ell clusters
    let ds = synth::uniform_cube(2000, 3, 2);
    let m = UniformMatroid::new(8);
    let k = 8;
    let tau = 32;
    let mut sizes = Vec::new();
    for ell in [1usize, 2, 4, 8] {
        let rep = mr_coreset(&ds, &m, k, cfg(ell, tau / ell, 5)).unwrap();
        // total clusters across shards stays ~tau -> coreset size stays flat
        sizes.push(rep.coreset.len());
        assert!(rep.coreset.len() <= tau * k + tau, "ell={ell}: {}", rep.coreset.len());
        let sol = maximal_independent(&m, &ds, &rep.coreset.indices, k);
        assert_eq!(sol.len(), k);
    }
    let max = *sizes.iter().max().unwrap() as f64;
    let min = *sizes.iter().min().unwrap() as f64;
    assert!(max / min < 2.5, "coreset size unstable across ell: {sizes:?}");
}

#[test]
fn local_memory_shrinks_with_parallelism() {
    let ds = synth::uniform_cube(4000, 2, 3);
    let m = UniformMatroid::new(4);
    let mut prev = usize::MAX;
    for ell in [1usize, 2, 4, 8, 16] {
        let rep = mr_coreset(&ds, &m, 4, cfg(ell, 4, 7)).unwrap();
        assert!(rep.local_memory_points <= prev);
        assert!(rep.local_memory_points <= 4000usize.div_ceil(ell));
        prev = rep.local_memory_points;
    }
}

#[test]
fn makespan_not_worse_than_single_worker() {
    // coarse scaling check (thread scheduling noise tolerated by margin)
    let ds = synth::uniform_cube(6000, 4, 4);
    let m = UniformMatroid::new(6);
    let r1 = mr_coreset(&ds, &m, 6, cfg(1, 16, 9)).unwrap();
    let r8 = mr_coreset(&ds, &m, 6, cfg(8, 2, 9)).unwrap();
    assert!(
        r8.makespan_round1 <= r1.makespan_round1,
        "8-worker makespan {:?} > 1-worker {:?}",
        r8.makespan_round1,
        r1.makespan_round1
    );
}

#[test]
fn different_seeds_shuffle_shards() {
    let ds = synth::uniform_cube(500, 2, 5);
    let m = UniformMatroid::new(4);
    let a = mr_coreset(&ds, &m, 4, cfg(4, 4, 1)).unwrap();
    let b = mr_coreset(&ds, &m, 4, cfg(4, 4, 2)).unwrap();
    assert_ne!(a.coreset.indices, b.coreset.indices);
}

#[test]
fn worker_times_reported_for_each_shard() {
    let ds = synth::uniform_cube(1000, 2, 6);
    let m = UniformMatroid::new(4);
    let rep = mr_coreset(&ds, &m, 4, cfg(5, 4, 11)).unwrap();
    assert_eq!(rep.worker_times.len(), 5);
    assert_eq!(rep.shard_coreset_sizes.len(), 5);
    // reducer-side quality accounting: one engine-backed sum-diversity per
    // shard coreset, strictly positive on spread-out random shards
    assert_eq!(rep.shard_coreset_diversities.len(), 5);
    assert!(rep.shard_coreset_diversities.iter().all(|&d| d > 0.0));
    assert_eq!(rep.rounds, 1);
    assert!(rep.wall_time >= std::time::Duration::ZERO);
}
