//! Engine equivalence: `BatchEngine` vs the `ScalarEngine` oracle.
//!
//! The batch backend's contract is stronger than tolerance: on the
//! min-fold (`update_min` / `update_min_block`) and sum (`sums_to_set`)
//! paths it must reproduce the oracle's `mind` / `arg` arrays **exactly**
//! — same f32 per-distance values (same f64 formulas, same accumulation
//! order) and the same left-to-right fold over centers within any chunk —
//! regardless of chunk boundaries or worker count.  Only the expanded-form
//! `pairwise_block` tile is tolerance-checked.

use matroid_coreset::core::{Dataset, Metric};
use matroid_coreset::data::synth;
use matroid_coreset::runtime::engine::{DistanceEngine, ScalarEngine};
use matroid_coreset::runtime::BatchEngine;
use matroid_coreset::util::rng::Rng;

/// A dataset under `metric` with an awkward n (not a multiple of the
/// batch point block) and a nontrivial dim.
fn dataset(metric: Metric, n: usize, dim: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let coords: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32).collect();
    Dataset::new(dim, metric, coords, vec![vec![0]; n], 1, "equiv")
}

fn fold_centers(n: usize) -> Vec<(usize, u32)> {
    // spread across the dataset, including both ends and repeats of id order
    vec![
        (0, 0),
        (n / 7, 1),
        (n / 3, 2),
        (n / 2, 3),
        (n - 2, 4),
        (n - 1, 5),
        (17.min(n - 1), 6),
        (n / 5, 7),
    ]
}

#[test]
fn update_min_exact_equality_both_metrics() {
    for metric in [Metric::Euclidean, Metric::Cosine] {
        // 20_011 is prime: never a multiple of the 1024-point cache block
        // or any worker span, so every chunk boundary case is exercised
        let ds = dataset(metric, 20_011, 19, 1);
        let batch = BatchEngine::for_dataset(&ds);
        let scalar = ScalarEngine::new();
        let n = ds.n();
        let (mut mb, mut ab) = (vec![f32::INFINITY; n], vec![u32::MAX; n]);
        let (mut ms, mut as_) = (vec![f32::INFINITY; n], vec![u32::MAX; n]);
        for &(c, id) in &fold_centers(n) {
            batch.update_min(&ds, c, id, &mut mb, &mut ab).unwrap();
            scalar.update_min(&ds, c, id, &mut ms, &mut as_).unwrap();
            assert_eq!(mb, ms, "mind diverged on {metric:?} after center {id}");
            assert_eq!(ab, as_, "arg diverged on {metric:?} after center {id}");
        }
    }
}

#[test]
fn update_min_block_equals_sequential_folds() {
    for metric in [Metric::Euclidean, Metric::Cosine] {
        let ds = dataset(metric, 9_973, 11, 2);
        let batch = BatchEngine::for_dataset(&ds);
        let centers = fold_centers(ds.n());
        let n = ds.n();
        let (mut mb, mut ab) = (vec![f32::INFINITY; n], vec![u32::MAX; n]);
        batch.update_min_block(&ds, &centers, &mut mb, &mut ab).unwrap();
        let scalar = ScalarEngine::new();
        let (mut ms, mut as_) = (vec![f32::INFINITY; n], vec![u32::MAX; n]);
        for &(c, id) in &centers {
            scalar.update_min(&ds, c, id, &mut ms, &mut as_).unwrap();
        }
        assert_eq!(mb, ms);
        assert_eq!(ab, as_);
    }
}

#[test]
fn thread_count_cannot_change_output() {
    // points are independent under the fold, so 1-thread and many-thread
    // runs must agree bit-for-bit — the determinism guarantee the GMM
    // trajectory (argmax over mind) relies on
    for metric in [Metric::Euclidean, Metric::Cosine] {
        let ds = dataset(metric, 30_011, 13, 3);
        let single = BatchEngine::with_threads(&ds, 1);
        let many = BatchEngine::with_threads(&ds, 8);
        let n = ds.n();
        let centers = fold_centers(n);
        let (mut m1, mut a1) = (vec![f32::INFINITY; n], vec![u32::MAX; n]);
        let (mut m8, mut a8) = (vec![f32::INFINITY; n], vec![u32::MAX; n]);
        single.update_min_block(&ds, &centers, &mut m1, &mut a1).unwrap();
        many.update_min_block(&ds, &centers, &mut m8, &mut a8).unwrap();
        assert_eq!(m1, m8);
        assert_eq!(a1, a8);

        let cands: Vec<usize> = (0..n).step_by(3).collect();
        let set: Vec<usize> = centers.iter().map(|&(c, _)| c).collect();
        let s1 = single.sums_to_set(&ds, &cands, &set).unwrap();
        let s8 = many.sums_to_set(&ds, &cands, &set).unwrap();
        assert_eq!(s1, s8);
    }
}

#[test]
fn sums_to_set_exactly_matches_oracle() {
    for metric in [Metric::Euclidean, Metric::Cosine] {
        let ds = dataset(metric, 4_001, 23, 4);
        let batch = BatchEngine::for_dataset(&ds);
        let scalar = ScalarEngine::new();
        let cands: Vec<usize> = (0..ds.n()).collect();
        let set: Vec<usize> = vec![5, 1_000, 2_000, 4_000, 5]; // repeat allowed
        let sb = batch.sums_to_set(&ds, &cands, &set).unwrap();
        let ss = scalar.sums_to_set(&ds, &cands, &set).unwrap();
        assert_eq!(sb, ss, "sums diverged on {metric:?}");
    }
}

#[test]
fn pairwise_block_within_tolerance_of_oracle() {
    for metric in [Metric::Euclidean, Metric::Cosine] {
        let ds = dataset(metric, 2_003, 27, 5);
        let batch = BatchEngine::for_dataset(&ds);
        let rows: Vec<usize> = (0..ds.n()).step_by(7).collect();
        let cols: Vec<usize> = vec![0, 3, 500, 1_000, 2_002];
        let tile = batch.pairwise_block(&ds, &rows, &cols).unwrap();
        for (r, &i) in rows.iter().enumerate() {
            for (c, &j) in cols.iter().enumerate() {
                let want = ds.dist(i, j);
                let got = tile[r * cols.len() + c] as f64;
                // expanded form + f32 narrowing: loose near 0, tight elsewhere
                assert!(
                    (got - want).abs() <= 1e-4 * want.max(1e-2),
                    "{metric:?} d({i},{j}): batch {got} vs oracle {want}"
                );
            }
        }
    }
}

#[test]
fn pairwise_block_self_distance_clamps_to_zero() {
    // the expanded Euclidean form can go (slightly) negative under
    // cancellation; the clamp must keep d(i, i) finite and ~0
    let ds = dataset(Metric::Euclidean, 257, 33, 6);
    let batch = BatchEngine::for_dataset(&ds);
    let idx: Vec<usize> = (0..ds.n()).collect();
    let tile = batch.pairwise_block(&ds, &idx, &idx).unwrap();
    for i in 0..ds.n() {
        let d = tile[i * ds.n() + i];
        assert!(d.is_finite() && d >= 0.0 && d < 1e-3, "d({i},{i}) = {d}");
    }
}

#[test]
fn seq_coreset_identical_across_engines() {
    use matroid_coreset::algo::seq_coreset::seq_coreset;
    use matroid_coreset::algo::Budget;
    use matroid_coreset::matroid::PartitionMatroid;

    let ds = synth::clustered(5_000, 6, 10, 0.12, 4, 7);
    let m = PartitionMatroid::new(vec![3; 4]);
    let a = seq_coreset(&ds, &m, 6, Budget::Clusters(20), &ScalarEngine::new()).unwrap();
    let b = seq_coreset(&ds, &m, 6, Budget::Clusters(20), &BatchEngine::for_dataset(&ds)).unwrap();
    assert_eq!(a.indices, b.indices);
    assert_eq!(a.n_clusters, b.n_clusters);
    assert_eq!(a.radius, b.radius);
}
