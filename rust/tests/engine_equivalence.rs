//! Engine equivalence: `BatchEngine` vs the `ScalarEngine` oracle.
//!
//! The batch backend's contract is stronger than tolerance: on **every**
//! path — min-fold (`update_min` / `update_min_block`), sums
//! (`sums_to_set`), pairwise tiles (`pairwise_block`), and the exact-f64
//! column blocks of the incremental AMT path (`dists_to_points`) — it must
//! reproduce the oracle **exactly**: same f32 per-distance values (same
//! f64 formulas, same accumulation order) and the same left-to-right fold
//! over centers within any chunk, regardless of chunk boundaries or
//! worker count.
//!
//! The diversity-evaluator section extends the pin to the consumer layer:
//! the `pairwise_block`-built submatrix and all five Table-1 objective
//! values must be bit-identical between the scalar oracle and the batch
//! backend (odd sizes and the k = 0/1/2 edge cases included), and an
//! evaluation-count regression pins that the evaluator does no duplicate
//! distance work.
//!
//! The *per-primitive* backend matrix (every primitive x metric x edge
//! case, for every registered backend under its declared contract) has
//! been extracted into the reusable conformance harness —
//! `runtime::conformance`, driven by `rust/tests/engine_conformance.rs`.
//! This file remains the deep large-`n` batch-vs-scalar pin plus the
//! consumer-layer (evaluator / seq_coreset) identity checks.

use matroid_coreset::algo::exhaustive::exhaustive_best;
use matroid_coreset::core::{Dataset, Metric};
use matroid_coreset::data::synth;
use matroid_coreset::diversity::{Evaluator, Objective, ALL_OBJECTIVES};
use matroid_coreset::matroid::UniformMatroid;
use matroid_coreset::runtime::engine::{DistanceEngine, ScalarEngine};
use matroid_coreset::runtime::{BatchEngine, SimdEngine};
use matroid_coreset::util::rng::Rng;

/// A dataset under `metric` with an awkward n (not a multiple of the
/// batch point block) and a nontrivial dim.
fn dataset(metric: Metric, n: usize, dim: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let coords: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32).collect();
    Dataset::new(dim, metric, coords, vec![vec![0]; n], 1, "equiv")
}

fn fold_centers(n: usize) -> Vec<(usize, u32)> {
    // spread across the dataset, including both ends and repeats of id order
    vec![
        (0, 0),
        (n / 7, 1),
        (n / 3, 2),
        (n / 2, 3),
        (n - 2, 4),
        (n - 1, 5),
        (17.min(n - 1), 6),
        (n / 5, 7),
    ]
}

#[test]
fn update_min_exact_equality_both_metrics() {
    for metric in [Metric::Euclidean, Metric::Cosine] {
        // 20_011 is prime: never a multiple of the 1024-point cache block
        // or any worker span, so every chunk boundary case is exercised
        let ds = dataset(metric, 20_011, 19, 1);
        let batch = BatchEngine::for_dataset(&ds);
        let scalar = ScalarEngine::new();
        let n = ds.n();
        let (mut mb, mut ab) = (vec![f32::INFINITY; n], vec![u32::MAX; n]);
        let (mut ms, mut as_) = (vec![f32::INFINITY; n], vec![u32::MAX; n]);
        for &(c, id) in &fold_centers(n) {
            batch.update_min(&ds, c, id, &mut mb, &mut ab).unwrap();
            scalar.update_min(&ds, c, id, &mut ms, &mut as_).unwrap();
            assert_eq!(mb, ms, "mind diverged on {metric:?} after center {id}");
            assert_eq!(ab, as_, "arg diverged on {metric:?} after center {id}");
        }
    }
}

#[test]
fn update_min_block_equals_sequential_folds() {
    for metric in [Metric::Euclidean, Metric::Cosine] {
        let ds = dataset(metric, 9_973, 11, 2);
        let batch = BatchEngine::for_dataset(&ds);
        let centers = fold_centers(ds.n());
        let n = ds.n();
        let (mut mb, mut ab) = (vec![f32::INFINITY; n], vec![u32::MAX; n]);
        batch.update_min_block(&ds, &centers, &mut mb, &mut ab).unwrap();
        let scalar = ScalarEngine::new();
        let (mut ms, mut as_) = (vec![f32::INFINITY; n], vec![u32::MAX; n]);
        for &(c, id) in &centers {
            scalar.update_min(&ds, c, id, &mut ms, &mut as_).unwrap();
        }
        assert_eq!(mb, ms);
        assert_eq!(ab, as_);
    }
}

#[test]
fn thread_count_cannot_change_output() {
    // points are independent under the fold, so 1-thread and many-thread
    // runs must agree bit-for-bit — the determinism guarantee the GMM
    // trajectory (argmax over mind) relies on
    for metric in [Metric::Euclidean, Metric::Cosine] {
        let ds = dataset(metric, 30_011, 13, 3);
        let single = BatchEngine::with_threads(&ds, 1);
        let many = BatchEngine::with_threads(&ds, 8);
        let n = ds.n();
        let centers = fold_centers(n);
        let (mut m1, mut a1) = (vec![f32::INFINITY; n], vec![u32::MAX; n]);
        let (mut m8, mut a8) = (vec![f32::INFINITY; n], vec![u32::MAX; n]);
        single.update_min_block(&ds, &centers, &mut m1, &mut a1).unwrap();
        many.update_min_block(&ds, &centers, &mut m8, &mut a8).unwrap();
        assert_eq!(m1, m8);
        assert_eq!(a1, a8);

        let cands: Vec<usize> = (0..n).step_by(3).collect();
        let set: Vec<usize> = centers.iter().map(|&(c, _)| c).collect();
        let s1 = single.sums_to_set(&ds, &cands, &set).unwrap();
        let s8 = many.sums_to_set(&ds, &cands, &set).unwrap();
        assert_eq!(s1, s8);
    }
}

#[test]
fn sums_to_set_exactly_matches_oracle() {
    for metric in [Metric::Euclidean, Metric::Cosine] {
        let ds = dataset(metric, 4_001, 23, 4);
        let batch = BatchEngine::for_dataset(&ds);
        let scalar = ScalarEngine::new();
        let cands: Vec<usize> = (0..ds.n()).collect();
        let set: Vec<usize> = vec![5, 1_000, 2_000, 4_000, 5]; // repeat allowed
        let sb = batch.sums_to_set(&ds, &cands, &set).unwrap();
        let ss = scalar.sums_to_set(&ds, &cands, &set).unwrap();
        assert_eq!(sb, ss, "sums diverged on {metric:?}");
    }
}

#[test]
fn pairwise_block_bit_identical_to_oracle() {
    for metric in [Metric::Euclidean, Metric::Cosine] {
        let ds = dataset(metric, 2_003, 27, 5);
        let batch = BatchEngine::for_dataset(&ds);
        let scalar = ScalarEngine::new();
        let rows: Vec<usize> = (0..ds.n()).step_by(7).collect();
        let cols: Vec<usize> = vec![0, 3, 500, 1_000, 2_002];
        let tb = batch.pairwise_block(&ds, &rows, &cols).unwrap();
        let ts = scalar.pairwise_block(&ds, &rows, &cols).unwrap();
        assert_eq!(tb, ts, "pairwise tile diverged on {metric:?}");
    }
}

// ---- dists_to_points section -----------------------------------------

#[test]
fn dists_to_points_bit_identical_to_oracle() {
    for metric in [Metric::Euclidean, Metric::Cosine] {
        // 20_011 is prime, so the threaded id chunks never align with the
        // worker span; duplicate targets are allowed
        let ds = dataset(metric, 20_011, 17, 10);
        let batch = BatchEngine::for_dataset(&ds);
        let scalar = ScalarEngine::new();
        // duplicate ids and duplicate targets are both allowed
        let mut ids: Vec<usize> = (0..ds.n()).collect();
        ids.push(3);
        ids.push(500);
        let targets: Vec<usize> = vec![3, 500, 3, 20_010, 7_777];
        let b = batch.dists_to_points(&ds, &ids, &targets).unwrap();
        let s = scalar.dists_to_points(&ds, &ids, &targets).unwrap();
        assert_eq!(b, s, "dists_to_points diverged on {metric:?}");
        // the f64 block agrees with the Dataset oracle off-diagonal and
        // pins self-pairs to a true zero (cosine d(x, x) is ~1e-8 raw)
        for (c, &t) in targets.iter().enumerate() {
            assert_eq!(b[t * targets.len() + c], 0.0, "{metric:?}: self-pair ({t},{t})");
        }
        for &i in &[0usize, 1, 9_999, 20_010] {
            for (c, &t) in targets.iter().enumerate() {
                let want = if i == t { 0.0 } else { ds.dist(i, t) };
                assert_eq!(b[i * targets.len() + c], want, "{metric:?}: entry ({i},{t})");
            }
        }
        // the duplicated id rows reproduce the original rows exactly
        // (including their self-pair zeros against targets 3 and 500)
        let w = targets.len();
        assert_eq!(&b[ds.n() * w..(ds.n() + 1) * w], &b[3 * w..4 * w]);
        assert_eq!(&b[(ds.n() + 1) * w..(ds.n() + 2) * w], &b[500 * w..501 * w]);
    }
}

#[test]
fn dists_to_points_thread_count_cannot_change_output() {
    let ds = dataset(Metric::Cosine, 30_011, 13, 11);
    let single = BatchEngine::with_threads(&ds, 1);
    let many = BatchEngine::with_threads(&ds, 8);
    let ids: Vec<usize> = (0..ds.n()).step_by(2).collect(); // odd count
    let targets: Vec<usize> = vec![1, 2, 30_000];
    let a = single.dists_to_points(&ds, &ids, &targets).unwrap();
    let b = many.dists_to_points(&ds, &ids, &targets).unwrap();
    assert_eq!(a, b);
}

#[test]
fn dists_to_points_row_sums_match_sums_to_set_bitwise() {
    // the incremental AMT re-anchor contract at the engine level: summing
    // a block row in target order (true-zero self entries included) is
    // bit-identical to the corresponding sums_to_set entry
    for metric in [Metric::Euclidean, Metric::Cosine] {
        let ds = dataset(metric, 4_001, 9, 12);
        let batch = BatchEngine::for_dataset(&ds);
        let ids: Vec<usize> = (0..ds.n()).collect();
        let set: Vec<usize> = vec![5, 1_000, 2_000, 4_000];
        let block = batch.dists_to_points(&ds, &ids, &set).unwrap();
        let sums = batch.sums_to_set(&ds, &ids, &set).unwrap();
        for (r, &want) in sums.iter().enumerate() {
            let resum: f64 = block[r * set.len()..(r + 1) * set.len()].iter().sum();
            assert!(
                resum.to_bits() == want.to_bits(),
                "{metric:?} row {r}: resum {resum} != sums_to_set {want}"
            );
        }
    }
}

#[test]
fn pairwise_block_self_distance_exactly_zero() {
    // the exact difference form makes d(i, i) a true zero on the Euclidean
    // path (the old expanded form only guaranteed ~0 under a clamp)
    let ds = dataset(Metric::Euclidean, 257, 33, 6);
    let batch = BatchEngine::for_dataset(&ds);
    let idx: Vec<usize> = (0..ds.n()).collect();
    let tile = batch.pairwise_block(&ds, &idx, &idx).unwrap();
    for i in 0..ds.n() {
        assert_eq!(tile[i * ds.n() + i], 0.0, "d({i},{i}) not exactly zero");
    }
}

// ---- diversity-evaluator section -------------------------------------

#[test]
fn diversity_evaluator_bit_identical_across_engines() {
    // random datasets and sets, both metrics, odd sizes and the k = 0/1/2
    // edge cases: the submatrix and every Table-1 objective value must be
    // bit-identical between the scalar oracle and the batch backend
    for metric in [Metric::Euclidean, Metric::Cosine] {
        let ds = dataset(metric, 601, 9, 7);
        let batch = BatchEngine::for_dataset(&ds);
        let scalar = ScalarEngine::new();
        let es = Evaluator::new(&scalar);
        let eb = Evaluator::new(&batch);
        let mut rng = Rng::new(11);
        for k in [0usize, 1, 2, 3, 5, 8, 13, 17] {
            let set = rng.sample_indices(ds.n(), k);
            assert_eq!(
                es.submatrix(&ds, &set).unwrap(),
                eb.submatrix(&ds, &set).unwrap(),
                "submatrix diverged on {metric:?} k={k}"
            );
            for obj in ALL_OBJECTIVES {
                let a = es.diversity(&ds, &set, obj).unwrap();
                let b = eb.diversity(&ds, &set, obj).unwrap();
                assert!(
                    a.to_bits() == b.to_bits(),
                    "{metric:?} {obj:?} k={k}: scalar {a} vs batch {b}"
                );
            }
            let alla = es.diversity_all(&ds, &set).unwrap();
            let allb = eb.diversity_all(&ds, &set).unwrap();
            assert_eq!(alla, allb, "diversity_all diverged on {metric:?} k={k}");
        }
    }
}

#[test]
fn diversity_evaluator_threaded_tile_bit_identical() {
    // k^2 large enough that the batch tile fans out over worker threads;
    // chunk boundaries must not change a bit of the submatrix or of the
    // objectives evaluated from it (bipartition is skipped: its heuristic
    // is O(k^4) at this size, and it reads the same tile anyway)
    let ds = dataset(Metric::Euclidean, 2_011, 15, 8);
    let batch = BatchEngine::for_dataset(&ds);
    let scalar = ScalarEngine::new();
    let es = Evaluator::new(&scalar);
    let eb = Evaluator::new(&batch);
    let mut rng = Rng::new(13);
    let set = rng.sample_indices(ds.n(), 131);
    assert_eq!(
        es.submatrix(&ds, &set).unwrap(),
        eb.submatrix(&ds, &set).unwrap()
    );
    for obj in [
        Objective::Sum,
        Objective::Star,
        Objective::Tree,
        Objective::Cycle,
        Objective::RemoteEdge,
    ] {
        let a = es.diversity(&ds, &set, obj).unwrap();
        let b = eb.diversity(&ds, &set, obj).unwrap();
        assert!(a.to_bits() == b.to_bits(), "{obj:?}: {a} vs {b}");
    }
}

#[test]
fn evaluator_distance_evaluation_counts() {
    // the dedup regression: the submatrix is built once and reused —
    // counted through the scalar engine's call counter
    let ds = dataset(Metric::Euclidean, 60, 3, 9);
    let e = ScalarEngine::new();
    let ev = Evaluator::new(&e);
    let set: Vec<usize> = (0..9).collect();

    ev.submatrix(&ds, &set).unwrap();
    assert_eq!(
        e.dist_evals(),
        9 * 8 / 2,
        "submatrix is one symmetric tile: strict upper triangle only"
    );

    e.reset_dist_evals();
    ev.diversity_all(&ds, &set).unwrap();
    assert_eq!(
        e.dist_evals(),
        9 * 8 + 9 * 8 / 2,
        "all six objectives = one sums pass + one symmetric tile (the \
         remote-edge min reads the same tile); the pre-evaluator code \
         re-walked Dataset::dist per objective and per star center"
    );

    e.reset_dist_evals();
    let m = UniformMatroid::new(4);
    let cands: Vec<usize> = (0..ds.n()).collect();
    exhaustive_best(&ds, &m, 4, &cands, Objective::Tree, &e).unwrap();
    assert_eq!(
        e.dist_evals(),
        (60 * 59 / 2 + 4 * 3 / 2) as u64,
        "exhaustive = one symmetric t x t candidate tile (every leaf \
         evaluates from it) + one k x k re-score of the winner"
    );
}

#[test]
fn seq_coreset_identical_across_engines() {
    use matroid_coreset::algo::seq_coreset::seq_coreset;
    use matroid_coreset::algo::Budget;
    use matroid_coreset::matroid::PartitionMatroid;

    let ds = synth::clustered(5_000, 6, 10, 0.12, 4, 7);
    let m = PartitionMatroid::new(vec![3; 4]);
    let a = seq_coreset(&ds, &m, 6, Budget::Clusters(20), &ScalarEngine::new()).unwrap();
    let b = seq_coreset(&ds, &m, 6, Budget::Clusters(20), &BatchEngine::for_dataset(&ds)).unwrap();
    assert_eq!(a.indices, b.indices);
    assert_eq!(a.n_clusters, b.n_clusters);
    assert_eq!(a.radius, b.radius);
    // simd is bit-exact on Euclidean datasets, so the GMM trajectory (an
    // argmax over the folded min-dists) cannot move either
    let c = seq_coreset(&ds, &m, 6, Budget::Clusters(20), &SimdEngine::for_dataset(&ds)).unwrap();
    assert_eq!(a.indices, c.indices);
    assert_eq!(a.radius, c.radius);
}

#[test]
fn remote_edge_engine_independent_and_matches_reference() {
    // the new max-min objective on every CPU backend: bit-identical
    // values, and equal to an index-pair min over Dataset::dist upcast
    // the same way the tile is (f32 then f64) — the reference the tile
    // path must reproduce
    for metric in [Metric::Euclidean, Metric::Cosine] {
        let ds = dataset(metric, 401, 7, 21);
        let scalar = ScalarEngine::new();
        let batch = BatchEngine::for_dataset(&ds);
        let mut rng = Rng::new(23);
        for k in [2usize, 3, 7, 12] {
            let set = rng.sample_indices(ds.n(), k);
            let a = Evaluator::new(&scalar)
                .diversity(&ds, &set, Objective::RemoteEdge)
                .unwrap();
            let b = Evaluator::new(&batch)
                .diversity(&ds, &set, Objective::RemoteEdge)
                .unwrap();
            assert!(a.to_bits() == b.to_bits(), "{metric:?} k={k}: {a} vs {b}");
            if metric == Metric::Euclidean {
                let c = Evaluator::new(&SimdEngine::for_dataset(&ds))
                    .diversity(&ds, &set, Objective::RemoteEdge)
                    .unwrap();
                assert!(a.to_bits() == c.to_bits(), "simd k={k}: {a} vs {c}");
            }
            let mut reference = f64::INFINITY;
            for (i, &x) in set.iter().enumerate() {
                for &y in &set[i + 1..] {
                    reference = reference.min(f64::from(ds.dist(x, y) as f32));
                }
            }
            assert!(
                a.to_bits() == reference.to_bits(),
                "{metric:?} k={k}: tile min {a} vs pairwise reference {reference}"
            );
        }
    }
}

#[test]
fn diversity_evaluator_bit_identical_under_simd_on_euclidean() {
    // the consumer-layer restatement of the simd Euclidean contract: the
    // submatrix and every Table-1 objective value must match the oracle
    // bit for bit (cosine is tolerance-level and covered by the
    // conformance suite instead)
    let ds = dataset(Metric::Euclidean, 601, 9, 7);
    let simd = SimdEngine::for_dataset(&ds);
    let scalar = ScalarEngine::new();
    let es = Evaluator::new(&scalar);
    let ev = Evaluator::new(&simd);
    let mut rng = Rng::new(17);
    for k in [0usize, 1, 2, 3, 5, 8, 13, 17] {
        let set = rng.sample_indices(ds.n(), k);
        assert_eq!(
            es.submatrix(&ds, &set).unwrap(),
            ev.submatrix(&ds, &set).unwrap(),
            "submatrix diverged under simd at k={k}"
        );
        for obj in ALL_OBJECTIVES {
            let a = es.diversity(&ds, &set, obj).unwrap();
            let b = ev.diversity(&ds, &set, obj).unwrap();
            assert!(
                a.to_bits() == b.to_bits(),
                "simd {obj:?} k={k}: scalar {a} vs simd {b}"
            );
        }
    }
}
