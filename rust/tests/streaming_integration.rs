//! Integration: streaming semantics — one pass, sublinear memory, order
//! robustness, and the quality/size trade governed by tau (paper §5.2).

use matroid_coreset::algo::local_search::{local_search_sum, LocalSearchParams};
use matroid_coreset::coordinator::{build_dataset, build_matroid, DatasetSpec, MatroidSpec};
use matroid_coreset::data::synth;
use matroid_coreset::diversity::sum_diversity;
use matroid_coreset::matroid::{Matroid, UniformMatroid};
use matroid_coreset::runtime::BatchEngine;
use matroid_coreset::streaming::{run_stream, StreamMode};
use matroid_coreset::util::rng::Rng;

#[test]
fn quality_improves_with_tau_fig2_shape() {
    // Figure 2's headline: larger tau -> better (and more concentrated)
    // solution quality. Checked as a trend over tau in {4, 16, 64}.
    let ds = synth::clustered(3000, 4, 32, 0.15, 1, 1);
    let m = UniformMatroid::new(8);
    let k = 8;
    let mut rng = Rng::new(7);
    let mut means = Vec::new();
    for tau in [4usize, 16, 64] {
        let mut divs = Vec::new();
        for _ in 0..3 {
            let order = rng.permutation(ds.n());
            let rep = run_stream(&ds, &m, k, StreamMode::Tau(tau), &order);
            let mut rng2 = Rng::new(42);
            let res = local_search_sum(
                &ds,
                &m,
                k,
                &rep.coreset.indices,
                &BatchEngine::for_dataset(&ds),
                LocalSearchParams::default(),
                None,
                &mut rng2,
            )
            .unwrap();
            divs.push(res.diversity);
        }
        means.push(divs.iter().sum::<f64>() / divs.len() as f64);
    }
    assert!(
        means[2] >= means[0] * 0.999,
        "quality did not improve with tau: {means:?}"
    );
}

#[test]
fn memory_grows_with_tau_but_stays_sublinear() {
    let ds = synth::uniform_cube(5000, 3, 2);
    let m = UniformMatroid::new(6);
    let order: Vec<usize> = (0..ds.n()).collect();
    let mut prev_mem = 0;
    for tau in [8usize, 32, 128] {
        let rep = run_stream(&ds, &m, 6, StreamMode::Tau(tau), &order);
        assert!(rep.stats.peak_memory_points >= prev_mem / 2); // roughly monotone
        assert!(
            rep.stats.peak_memory_points < ds.n() / 4,
            "tau={tau}: memory {} not sublinear",
            rep.stats.peak_memory_points
        );
        prev_mem = rep.stats.peak_memory_points;
    }
}

#[test]
fn adversarial_orders_keep_feasibility() {
    let spec = DatasetSpec::Wikisim { n: 1000, seed: 3 };
    let ds = build_dataset(&spec).unwrap();
    let m = build_matroid(&MatroidSpec::Transversal, &ds);
    let k = 6;
    // sorted-by-first-coordinate order (worst case for diameter estimates)
    let mut sorted: Vec<usize> = (0..ds.n()).collect();
    sorted.sort_by(|&a, &b| {
        ds.point(a)[0]
            .partial_cmp(&ds.point(b)[0])
            .unwrap()
    });
    let reversed: Vec<usize> = sorted.iter().rev().copied().collect();
    for order in [&sorted, &reversed] {
        let rep = run_stream(&ds, &m, k, StreamMode::Tau(24), order);
        let sol = matroid_coreset::matroid::maximal_independent(&m, &ds, &rep.coreset.indices, k);
        assert_eq!(sol.len(), k, "stream order broke feasibility");
    }
}

#[test]
fn stream_vs_seq_quality_band() {
    // StreamCoreset uses an 8-approx clustering vs GMM's 2-approx, so its
    // quality may trail SeqCoreset slightly — but not collapse (Fig. 3).
    use matroid_coreset::algo::seq_coreset::seq_coreset;
    use matroid_coreset::algo::Budget;
    use matroid_coreset::runtime::ScalarEngine;

    let ds = synth::clustered(4000, 4, 24, 0.1, 1, 5);
    let m = UniformMatroid::new(6);
    let k = 6;
    let tau = 24;
    let seq = seq_coreset(&ds, &m, k, Budget::Clusters(tau), &ScalarEngine::new()).unwrap();
    let order: Vec<usize> = (0..ds.n()).collect();
    let stream = run_stream(&ds, &m, k, StreamMode::Tau(tau), &order);
    let finish = |cands: &[usize]| {
        let mut rng = Rng::new(1);
        local_search_sum(
            &ds, &m, k, cands,
            &ScalarEngine::new(),
            LocalSearchParams::default(), None, &mut rng,
        )
        .unwrap()
        .diversity
    };
    let d_seq = finish(&seq.indices);
    let d_stream = finish(&stream.coreset.indices);
    assert!(
        d_stream >= 0.75 * d_seq,
        "stream {d_stream} collapsed vs seq {d_seq}"
    );
}

#[test]
fn throughput_and_distance_eval_accounting() {
    let ds = synth::uniform_cube(2000, 2, 6);
    let m = UniformMatroid::new(4);
    let order: Vec<usize> = (0..ds.n()).collect();
    let rep = run_stream(&ds, &m, 4, StreamMode::Tau(16), &order);
    assert!(rep.throughput > 0.0);
    // distance evals ~ n * |Z| at most (plus restructures)
    let bound = (ds.n() * (16 + 4)) as u64 * 2;
    assert!(
        rep.stats.distance_evals <= bound,
        "evals {} exceed model bound {bound}",
        rep.stats.distance_evals
    );
}

#[test]
fn duplicate_heavy_stream_terminates_small() {
    // many duplicates: centers stay tiny, delegates bounded
    let mut coords = Vec::new();
    for i in 0..1000 {
        let v = (i % 5) as f32;
        coords.push(v);
        coords.push(-v);
    }
    let ds = matroid_coreset::core::Dataset::new(
        2,
        matroid_coreset::core::Metric::Euclidean,
        coords,
        vec![vec![0]; 1000],
        1,
        "dups",
    );
    let m = UniformMatroid::new(3);
    let order: Vec<usize> = (0..ds.n()).collect();
    let rep = run_stream(&ds, &m, 3, StreamMode::Tau(8), &order);
    assert!(rep.coreset.n_clusters <= 8);
    assert!(rep.coreset.len() <= 8 * 3 + 8);
    let sol = maximal_ind(&ds, &m, &rep.coreset.indices, 3);
    assert_eq!(sol.len(), 3);
    let div = sum_diversity(&ds, &sol);
    assert!(div > 0.0);
}

fn maximal_ind(
    ds: &matroid_coreset::core::Dataset,
    m: &dyn Matroid,
    items: &[usize],
    k: usize,
) -> Vec<usize> {
    matroid_coreset::matroid::maximal_independent(m, ds, items, k)
}
