//! Integration: the dynamic side of the coreset index — deletions,
//! rebuilds, retention (`matroid_coreset::index` + the window wrapper).
//!
//! Pins the acceptance properties of the dynamic subsystem:
//!
//! * **delete quality** — after tombstoning rows, the index root is as
//!   good a coreset of the *surviving* data as a one-shot SeqCoreset
//!   rebuilt from scratch on the survivors, within the same pinned ratio
//!   the append-only tests use, for every Table-1 objective;
//! * **amortized O(log) deletes** — a delete touches only the occupied
//!   levels (O(log segments)), and the analytic rebuild ledger equals
//!   the measured ScalarEngine oracle counter, pass for pass;
//! * **cache epoch** — an effective delete makes a cache hit impossible
//!   (epoch bump), a no-op delete leaves cached results valid;
//! * **window-as-retention** — a `LastSegments` index reproduces the
//!   `SlidingWindowCoreset` wrapper trajectory bit-exactly.

use std::collections::BTreeSet;

use matroid_coreset::algo::exhaustive::exhaustive_best;
use matroid_coreset::algo::seq_coreset::seq_coreset;
use matroid_coreset::algo::Budget;
use matroid_coreset::data::synth;
use matroid_coreset::diversity::{Objective, ALL_OBJECTIVES};
use matroid_coreset::index::{
    CoresetIndex, IndexConfig, LeafIngest, QueryService, QuerySpec, RetentionPolicy,
};
use matroid_coreset::matroid::{maximal_independent, PartitionMatroid, UniformMatroid};
use matroid_coreset::runtime::{EngineKind, ScalarEngine};
use matroid_coreset::streaming::SlidingWindowCoreset;

/// Same pin as `index_service.rs`: the dynamic root must stay within this
/// factor of the from-scratch optimum on the surviving rows.
const PINNED_RATIO: f64 = 0.5;

fn scalar_cfg(k_max: usize, tau: usize) -> IndexConfig {
    IndexConfig {
        engine: EngineKind::Scalar,
        leaf_ingest: LeafIngest::Seq,
        ..IndexConfig::new(k_max, tau)
    }
}

#[test]
fn delete_then_query_matches_rebuild_from_scratch_quality() {
    // the exact instance of index_service's quality pin
    let ds = synth::clustered(60, 2, 6, 0.05, 3, 1);
    let m = PartitionMatroid::new(vec![2, 2, 2]);
    let k = 4;

    let mut idx = CoresetIndex::new(&ds, &m, scalar_cfg(k, 12));
    let order: Vec<usize> = (0..ds.n()).collect();
    idx.ingest(&order, 15).unwrap();

    // tombstone every 4th row, then compare the standing root against a
    // one-shot coreset rebuilt from scratch on exactly the survivors
    let victims: Vec<usize> = (0..ds.n()).step_by(4).collect();
    let r = idx.delete(&victims).unwrap();
    assert_eq!(r.newly_dead, victims.len());
    let dead: BTreeSet<usize> = victims.iter().copied().collect();
    let survivors: Vec<usize> = (0..ds.n()).filter(|i| !dead.contains(i)).collect();

    let root = idx.root();
    assert!(root.iter().all(|i| !dead.contains(i)), "dead row leaked into root");
    assert_eq!(
        maximal_independent(&m, &ds, &root, k).len(),
        k,
        "delete broke root feasibility"
    );

    let view = ds.subset(&survivors);
    let scratch = seq_coreset(&view, &m, k, Budget::Epsilon(0.5), &ScalarEngine::new()).unwrap();
    let scratch_global: Vec<usize> = scratch.indices.iter().map(|&i| survivors[i]).collect();

    let scalar = ScalarEngine::new();
    for obj in ALL_OBJECTIVES {
        let scratch_opt = exhaustive_best(&ds, &m, k, &scratch_global, obj, &scalar)
            .unwrap()
            .diversity;
        let root_opt = exhaustive_best(&ds, &m, k, &root, obj, &scalar).unwrap().diversity;
        assert!(
            root_opt >= PINNED_RATIO * scratch_opt - 1e-9,
            "{obj:?}: dynamic root {root_opt} < {PINNED_RATIO} * from-scratch {scratch_opt}"
        );
    }

    // and against the brute-force optimum over all survivors, for sum
    let brute = exhaustive_best(&ds, &m, k, &survivors, Objective::Sum, &scalar)
        .unwrap()
        .diversity;
    let root_sum = exhaustive_best(&ds, &m, k, &root, Objective::Sum, &scalar)
        .unwrap()
        .diversity;
    assert!(
        root_sum >= PINNED_RATIO * brute - 1e-9,
        "sum: dynamic root {root_sum} < {PINNED_RATIO} * survivor brute-force {brute}"
    );
}

#[test]
fn delete_touches_only_occupied_levels() {
    let ds = synth::uniform_cube(840, 2, 11);
    let m = UniformMatroid::new(4);
    let mut idx = CoresetIndex::new(&ds, &m, scalar_cfg(4, 8));
    let order: Vec<usize> = (0..ds.n()).collect();
    // 21 segments = 0b10101: exactly 3 occupied binary-counter levels
    idx.ingest(&order, 40).unwrap();
    assert_eq!(idx.segments(), 21);
    let occupied = idx.levels().iter().flatten().count();
    assert_eq!(occupied, (21u32).count_ones() as usize);

    let root = idx.root();
    let r = idx.delete(&root[..2]).unwrap();
    // a delete scans each occupied level once — O(log segments), not
    // O(segments) and not O(points)
    assert_eq!(r.nodes_touched, occupied);
    let log2_bound = (usize::BITS - 21usize.leading_zeros()) as usize;
    assert!(
        r.nodes_touched <= log2_bound,
        "delete touched {} nodes > log bound {log2_bound}",
        r.nodes_touched
    );
    // receipt ledger is exactly reconstructible from its reduce log
    let analytic: u64 = r.reduce_log.iter().map(|&(n, c)| (n * c) as u64).sum();
    assert_eq!(r.dist_evals, analytic);
}

#[test]
fn rebuild_ledger_matches_the_scalar_engine_counter() {
    let ds = synth::uniform_cube(320, 2, 17);
    let m = UniformMatroid::new(4);
    let (k, tau) = (4usize, 8usize);
    let mut idx = CoresetIndex::new(&ds, &m, scalar_cfg(k, tau));
    let order: Vec<usize> = (0..ds.n()).collect();
    // 8 segments collapse into a single occupied level
    idx.ingest(&order, 40).unwrap();
    let node_indices = idx.levels().iter().flatten().next().unwrap().indices.clone();

    // kill 3/4 of the root: the lone node crosses the 0.5 live-fraction
    // threshold and rebuilds from its survivors
    let root = idx.root();
    let kill: Vec<usize> = root.iter().copied().take(root.len() * 3 / 4).collect();
    let r = idx.delete(&kill).unwrap();
    assert_eq!(r.rebuilds, 1);

    // replay the rebuild pass externally with the oracle counter: one
    // SeqCoreset over the node's live members under the reduce budget
    let dead: BTreeSet<usize> = kill.iter().copied().collect();
    let live: Vec<usize> = node_indices.iter().copied().filter(|i| !dead.contains(i)).collect();
    let probe = ScalarEngine::new();
    let view = ds.subset(&live);
    let cs = seq_coreset(&view, &m, k, Budget::Clusters(tau), &probe).unwrap();
    assert_eq!(
        probe.dist_evals(),
        r.dist_evals,
        "analytic rebuild ledger out of sync with the measured ScalarEngine counter"
    );
    assert_eq!(r.dist_evals, (cs.n_clusters * view.n()) as u64);
    assert_eq!(r.reduce_log, vec![(live.len(), cs.n_clusters)]);

    // and the rebuild itself is the deterministic replay of that pass
    let mut want: Vec<usize> = cs.indices.iter().map(|&i| live[i]).collect();
    want.sort_unstable();
    want.dedup();
    assert_eq!(idx.root(), want, "rebuilt node differs from its external replay");
}

#[test]
fn effective_delete_makes_cache_hits_impossible() {
    let ds = synth::clustered(200, 2, 4, 0.1, 3, 7);
    let m = PartitionMatroid::new(vec![2; 3]);
    let k = 4;
    let order: Vec<usize> = (0..ds.n()).collect();
    let mut svc = QueryService::new(CoresetIndex::new(&ds, &m, scalar_cfg(k, 10)));
    for chunk in order.chunks(50) {
        svc.append(chunk).unwrap();
    }

    let spec = QuerySpec::sum_local_search(k, EngineKind::Scalar);
    let cold = svc.query(&spec).unwrap();
    assert!(!cold.cache_hit);
    assert!(svc.query(&spec).unwrap().cache_hit);

    // kill a member of the served solution: the epoch bump must force the
    // next identical query cold, and the dead row out of its solution
    let victim = cold.result.solution[0];
    let dr = svc.delete(&[victim]).unwrap();
    assert!(dr.epoch > cold.epoch);
    let after = svc.query(&spec).unwrap();
    assert!(!after.cache_hit, "cache hit served across a delete");
    assert!(after.epoch > cold.epoch);
    assert!(!after.result.solution.contains(&victim));

    // deleting the same row again is a no-op: epoch holds, cache stays
    let noop = svc.delete(&[victim]).unwrap();
    assert_eq!(noop.newly_dead, 0);
    assert_eq!(noop.epoch, after.epoch);
    assert!(svc.query(&spec).unwrap().cache_hit, "no-op delete evicted the cache");
}

#[test]
fn last_segments_retention_reproduces_the_window_wrapper() {
    let ds = synth::uniform_cube(1000, 2, 1);
    let m = UniformMatroid::new(4);
    let (k, tau, block, w) = (4usize, 4usize, 100usize, 3usize);
    let mut sw = SlidingWindowCoreset::with_engine(&ds, &m, k, tau, block, w, EngineKind::Scalar);
    let cfg = IndexConfig {
        retention: RetentionPolicy::LastSegments(w),
        ..scalar_cfg(k, tau)
    };
    let mut idx = CoresetIndex::new(&ds, &m, cfg);

    let order: Vec<usize> = (0..ds.n()).collect();
    for chunk in order.chunks(block) {
        for &x in chunk {
            sw.push(x).unwrap();
        }
        idx.append(chunk).unwrap();
        // at a block boundary the wrapper's pending buffer is empty, so
        // its query is exactly the retained index root
        assert_eq!(sw.query(), idx.root(), "wrapper diverged from bare retention");
    }
    assert_eq!(sw.index().segments(), idx.segments());
    assert_eq!(sw.index().stats().expired_segments, idx.stats().expired_segments);
    assert_eq!(sw.window_start(), (idx.segments() - w) * block);
}
