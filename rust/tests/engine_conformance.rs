//! Cross-backend conformance: every registered [`DistanceEngine`] backend
//! runs the same per-primitive case matrix (all five primitives, both
//! metrics, odd/even n, dim 1, single-point and zero-distance datasets,
//! duplicate ids, self-pairs, empty shapes) against the scalar oracle
//! under its declared contract — see `runtime::conformance` for the
//! harness and `EngineKind::contract` for the per-backend contracts.
//!
//! CI runs this suite by name (`cargo test -q --test engine_conformance`)
//! so a backend regression fails with a readable job label.

use matroid_coreset::core::{Dataset, Metric};
use matroid_coreset::prop_assert;
use matroid_coreset::proptest::check;
use matroid_coreset::runtime::conformance::check_backend;
use matroid_coreset::runtime::{
    build_engine_with_threads, DistanceEngine, EngineKind, IdentityLevel, ScalarEngine,
};

// One named test per backend: a regression reads as
// `conformance_<backend>` in the CI log, not as a generic loop failure.

#[test]
fn conformance_scalar() {
    // the oracle through its own harness — a self-consistency check that
    // also guards the harness against drifting from the trait contract
    check_backend(EngineKind::Scalar).unwrap();
}

#[test]
fn conformance_batch() {
    check_backend(EngineKind::Batch).unwrap();
}

#[test]
fn conformance_simd() {
    check_backend(EngineKind::Simd).unwrap();
}

#[cfg(feature = "pjrt")]
#[test]
fn conformance_pjrt() {
    use matroid_coreset::runtime::{default_artifact_dir, Manifest};
    // same policy as the ablation bench: the backend needs the AOT
    // artifacts on disk; absence is an environment gap, not a regression
    if Manifest::load(default_artifact_dir()).is_err() {
        eprintln!("SKIP: pjrt artifacts missing (run `make artifacts`)");
        return;
    }
    check_backend(EngineKind::Pjrt).unwrap();
}

#[test]
fn registry_is_closed_under_parse() {
    let kinds = EngineKind::registered();
    assert!(kinds.contains(&EngineKind::Scalar));
    assert!(kinds.contains(&EngineKind::Batch));
    assert!(kinds.contains(&EngineKind::Simd));
    for &kind in kinds {
        assert_eq!(EngineKind::parse(kind.name()), Some(kind), "{}", kind.name());
    }
    assert_eq!(EngineKind::parse("nope"), None);
}

/// Differential fuzzing: random datasets and call shapes through **all**
/// registered backends simultaneously, each judged against the oracle
/// under its own contract.  Complements the fixed case matrix with the
/// shapes nobody thought to enumerate.
#[test]
fn prop_differential_all_backends_agree() {
    check("engine-differential", 25, |g| {
        let n = g.usize_in(2, 40);
        let dim = g.usize_in(1, 9);
        let metric = if g.rng.below(2) == 0 {
            Metric::Euclidean
        } else {
            Metric::Cosine
        };
        let coords = g.vec_f32(n * dim, 1.5);
        let ds = Dataset::new(dim, metric, coords, vec![vec![0]; n], 1, "fuzz");
        // random index lists with duplicates and self-pair overlaps
        let n_rows = g.usize_in(1, n);
        let rows: Vec<usize> = (0..n_rows).map(|_| g.rng.below(n)).collect();
        let n_cols = g.usize_in(1, 6);
        let cols: Vec<usize> = (0..n_cols).map(|_| g.rng.below(n)).collect();
        let center = g.rng.below(n);

        let oracle = ScalarEngine::new();
        let sums_o = oracle.sums_to_set(&ds, &rows, &cols).map_err(|e| e.to_string())?;
        let blk_o = oracle.dists_to_points(&ds, &rows, &cols).map_err(|e| e.to_string())?;
        let tile_o = oracle.pairwise_block(&ds, &rows, &cols).map_err(|e| e.to_string())?;
        let mut mind_o = vec![f32::INFINITY; n];
        let mut arg_o = vec![u32::MAX; n];
        oracle
            .update_min(&ds, center, 7, &mut mind_o, &mut arg_o)
            .map_err(|e| e.to_string())?;

        for &kind in EngineKind::registered() {
            if kind == EngineKind::Scalar {
                continue;
            }
            // pjrt without artifacts on disk cannot construct — skip it,
            // never fail the property for an environment gap
            let Ok(engine) = build_engine_with_threads(kind, &ds, 2) else {
                continue;
            };
            let level = kind.contract().for_metric(metric);
            let ok_f64 = |a: f64, b: f64, scale: f64| match level {
                IdentityLevel::BitExact => a.to_bits() == b.to_bits(),
                IdentityLevel::AbsTol(tol) => (a - b).abs() <= tol * scale,
            };
            let sums = engine.sums_to_set(&ds, &rows, &cols).map_err(|e| e.to_string())?;
            for (i, (a, b)) in sums.iter().zip(&sums_o).enumerate() {
                prop_assert!(
                    ok_f64(*a, *b, cols.len() as f64),
                    "{}/{metric:?}: sums[{i}] {a} vs oracle {b}",
                    kind.name()
                );
            }
            let blk = engine.dists_to_points(&ds, &rows, &cols).map_err(|e| e.to_string())?;
            for (i, (a, b)) in blk.iter().zip(&blk_o).enumerate() {
                prop_assert!(
                    ok_f64(*a, *b, 1.0),
                    "{}/{metric:?}: dists[{i}] {a} vs oracle {b}",
                    kind.name()
                );
            }
            let tile = engine.pairwise_block(&ds, &rows, &cols).map_err(|e| e.to_string())?;
            for (i, (a, b)) in tile.iter().zip(&tile_o).enumerate() {
                prop_assert!(
                    ok_f64(*a as f64, *b as f64, 1.0),
                    "{}/{metric:?}: tile[{i}] {a} vs oracle {b}",
                    kind.name()
                );
            }
            let mut mind = vec![f32::INFINITY; n];
            let mut arg = vec![u32::MAX; n];
            engine
                .update_min(&ds, center, 7, &mut mind, &mut arg)
                .map_err(|e| e.to_string())?;
            for (i, (a, b)) in mind.iter().zip(&mind_o).enumerate() {
                prop_assert!(
                    ok_f64(*a as f64, *b as f64, 1.0),
                    "{}/{metric:?}: mind[{i}] {a} vs oracle {b}",
                    kind.name()
                );
            }
            prop_assert!(
                arg.iter().all(|&a| a == 7),
                "{}/{metric:?}: single-center fold must assign every point",
                kind.name()
            );
        }
        Ok(())
    });
}
